//! Offline construction of the DFA mask store M₀ / M₁ (Definition 12).
//!
//! Construction (per §4.6 the one-time cost is O(|Q_Ω|·|V|·|Γ|^α)):
//!
//! 1. For every terminal τ and token t, walk t from τ's start state once,
//!    recording `suffmatch(τ, t, i)` = dmatch(t[i..], q₀^τ, {}) for every
//!    suffix start i — the "jump into the next terminal" primitive of
//!    Definition 10 condition 3.
//! 2. For every DFA state q and token t, walk t from q recording
//!    (a) whole-walk liveness (condition 1) and (b) the prefix positions
//!    where the walk sits in a final state (the split points of
//!    conditions 2/3).
//! 3. M₀ and M₁ bits then assemble from these tables without re-walking.
//!
//! Both passes are driven by the tokenizer's [`TokenTrie`]
//! (see `mask/trie.rs`): tokens sharing a prefix share every `dfa.step`,
//! dead bytes and non-live transitions prune whole subtrees, and sibling
//! edges in one byte class share one transition. The walk tables are
//! indexed by token, so DFS visit order is irrelevant and the output is
//! **bit-identical** to the naive per-token walk — which is kept as
//! [`MaskStore::build_reference`] and asserted equal in CI
//! (`rust/tests/trie_parity.rs`). `MaskStoreStats` reports the executed
//! step count against the naive `Σ|Q_Ω|·Σ|t|` bound.
//!
//! Identical masks are interned into a shared pool; tables store pool
//! indices. `MaskStoreStats` reports build time and memory for Table 5.
//!
//! # Storage formats
//!
//! Two serialised formats exist (see `docs/artifacts.md` for the byte-level
//! layout):
//!
//! - **`SYNCMSK2`** (current writer): index tables and the interned mask
//!   pool are 8-byte-aligned little-endian sections, so a store can be
//!   served either from owned vectors or **in place** from an `mmap`'d
//!   [`Blob`] ([`MaskStore::from_blob`]) — warm start costs header
//!   validation plus page faults, with zero per-mask copies. The header
//!   records `eos_id` and the build-relevant [`MaskStoreConfig`] fields so
//!   a stale, differently-configured cache can never be served.
//! - **`SYNCMSK1`** (legacy): unaligned; always deserialised with a copy.
//!   [`MaskStore::from_bytes`] keeps reading it; [`MaskStore::to_bytes_v1`]
//!   keeps writing it for format-stability tests.

use super::trie::{TokenTrie, TrieScratch, TrieWalkStats};
use crate::grammar::{Grammar, TermId, TermPattern};
use crate::regex::{Dfa, DEAD};
use crate::tokenizer::Tokenizer;
use crate::util::bitset::{BitSet, BitView};
use crate::util::blob::{pad8, Blob, BlobReader};
use std::collections::HashMap;
use std::sync::Arc;

/// Build options.
#[derive(Debug, Clone)]
pub struct MaskStoreConfig {
    /// Build M₁ (α = 1) in addition to M₀. Without it only 1-length
    /// sequences get precise masks (2-length fall back to M₀ semantics).
    pub with_m1: bool,
    /// Cap on token length considered for prefix-split positions (tokens
    /// longer than this are excluded from the store). Clamped to
    /// [`MaskStoreConfig::MAX_SPLIT_LEN`]; see
    /// [`MaskStoreConfig::effective_max_token_len`].
    pub max_token_len: usize,
    /// Worker threads for the per-(state, token) walk loop: 1 = serial
    /// (the default), 0 = one per available core, n = exactly n. The
    /// result is bit-identical across thread counts (sharded work merges
    /// in shard order, so the interned pool keeps first-occurrence order).
    pub threads: usize,
}

impl Default for MaskStoreConfig {
    fn default() -> Self {
        MaskStoreConfig { with_m1: true, max_token_len: 64, threads: 1 }
    }
}

impl MaskStoreConfig {
    /// Hard upper bound on per-token split positions: split bitmasks are
    /// 128-bit, holding positions 0..=127, so a token of up to 127 bytes
    /// keeps *every* split point including its final one.
    pub const MAX_SPLIT_LEN: usize = 127;

    /// The cap the build actually applies: `max_token_len` clamped so the
    /// split-position bitmask can represent the final split point of the
    /// longest admitted token.
    pub fn effective_max_token_len(&self) -> usize {
        self.max_token_len.min(Self::MAX_SPLIT_LEN)
    }

    /// Default options with the parallel build enabled (one worker per
    /// available core). Used by the artifact layer's offline compile.
    pub fn parallel() -> Self {
        MaskStoreConfig { threads: 0, ..MaskStoreConfig::default() }
    }
}

/// Creation-time/memory statistics (Table 5).
#[derive(Debug, Clone)]
pub struct MaskStoreStats {
    pub build_secs: f64,
    /// Worker threads the build actually used (0 after deserialisation).
    pub build_threads: usize,
    pub vocab_size: usize,
    pub num_dfa_states: usize,
    pub num_terminals: usize,
    pub unique_masks: usize,
    pub m0_entries: usize,
    pub m1_entries: usize,
    /// Bytes held by the interned mask pool + index tables.
    pub mem_bytes: usize,
    /// Bytes the tables would occupy without interning (paper's layout).
    pub raw_bytes: usize,
    /// True when the store is a borrowed view over a blob — lookups read
    /// the serialised bytes in place, no table was deserialised-by-copy.
    pub zero_copy: bool,
    /// True when that blob is an actual file mapping (the mmap fast
    /// path); false for an owned in-memory blob (e.g. the non-unix
    /// read-file fallback), where the file was still read+copied once.
    pub mapped: bool,
    /// `dfa.step` calls the pass-2 walk loop actually executed (0 after
    /// deserialisation — a loaded store walked nothing).
    pub walk_steps: u64,
    /// The brute-force pass-2 bound the naive builder is charged with:
    /// |items| · Σ token bytes. `naive_steps / walk_steps` is the
    /// compile-time win the trie delivers.
    pub naive_steps: u64,
    /// Trie nodes entered across all pass-2 walks (0 for the reference
    /// builder — it has no trie).
    pub trie_nodes_visited: u64,
    /// Token walks resolved by static dead-byte analysis, i.e. pruned
    /// before any step executed.
    pub pruned_dead_byte: u64,
}

/// Table storage: either owned vectors (built or copy-deserialised) or a
/// borrowed view into an 8-aligned [`Blob`] (the zero-copy warm path).
enum StoreData {
    Owned {
        offsets: Vec<u32>,
        m0: Vec<u32>,
        m1: Vec<u32>,
        /// Interned pool, flattened: mask `i` is words
        /// `[i*words_per, (i+1)*words_per)`.
        pool: Vec<u64>,
    },
    View {
        blob: Arc<Blob>,
        offsets: Sect,
        m0: Sect,
        m1: Sect,
        pool: Sect,
    },
}

/// A section of a blob: absolute byte offset + element count.
#[derive(Clone, Copy)]
struct Sect {
    off: usize,
    len: usize,
}

/// The precomputed DFA mask store.
pub struct MaskStore {
    vocab_size: usize,
    eos_id: u32,
    num_states: usize,
    nterms: usize,
    words_per: usize,
    with_m1: bool,
    /// Effective token-length cap the store was built with; `None` for
    /// legacy `SYNCMSK1` blobs, which did not record it.
    max_token_len: Option<usize>,
    data: StoreData,
    pub stats: MaskStoreStats,
}

const NONE: u32 = u32::MAX;

impl MaskStore {
    /// EOS token id (set on masks only via `eos_ok`).
    pub fn eos_id(&self) -> u32 {
        self.eos_id
    }

    /// Was the store built with M₁ tables?
    pub fn with_m1(&self) -> bool {
        self.with_m1
    }

    /// Effective token-length cap recorded in the store header (`None`
    /// for legacy blobs).
    pub fn max_token_len(&self) -> Option<usize> {
        self.max_token_len
    }

    // ---- table accessors (one match, then plain slices) ----------------

    fn offsets(&self) -> &[u32] {
        match &self.data {
            StoreData::Owned { offsets, .. } => offsets,
            StoreData::View { blob, offsets: s, .. } => {
                blob.u32s(s.off, s.len).expect("offsets section validated at load")
            }
        }
    }

    fn m0_tab(&self) -> &[u32] {
        match &self.data {
            StoreData::Owned { m0, .. } => m0,
            StoreData::View { blob, m0: s, .. } => {
                blob.u32s(s.off, s.len).expect("m0 section validated at load")
            }
        }
    }

    fn m1_tab(&self) -> &[u32] {
        match &self.data {
            StoreData::Owned { m1, .. } => m1,
            StoreData::View { blob, m1: s, .. } => {
                blob.u32s(s.off, s.len).expect("m1 section validated at load")
            }
        }
    }

    fn pool_words(&self) -> &[u64] {
        match &self.data {
            StoreData::Owned { pool, .. } => pool,
            StoreData::View { blob, pool: s, .. } => {
                blob.u64s(s.off, s.len).expect("pool section validated at load")
            }
        }
    }

    /// Borrowed view of interned mask `idx` — for a mapped store this
    /// reads straight out of the mapping.
    #[inline]
    fn pool_mask(&self, idx: u32) -> BitView<'_> {
        let start = idx as usize * self.words_per;
        BitView::new(&self.pool_words()[start..start + self.words_per], self.vocab_size)
    }

    #[inline]
    fn gidx(&self, term: TermId, q: u32) -> usize {
        (self.offsets()[term as usize] + q) as usize
    }

    /// Union `M₀(q_τ)` into `out`.
    #[inline]
    pub fn union_m0(&self, term: TermId, q: u32, out: &mut BitSet) {
        let idx = self.m0_tab()[self.gidx(term, q)];
        if idx != NONE {
            out.union_with_view(self.pool_mask(idx));
        }
    }

    /// Union `M₁(q_τ, τ_next)` into `out` (falls back to M₀ when M₁ was
    /// not built — a sound over-approximation).
    #[inline]
    pub fn union_m1(&self, term: TermId, q: u32, next: TermId, out: &mut BitSet) {
        if !self.with_m1 {
            return self.union_m0(term, q, out);
        }
        let idx = self.m1_tab()[self.gidx(term, q) * self.nterms + next as usize];
        if idx != NONE {
            out.union_with_view(self.pool_mask(idx));
        }
    }

    /// Membership test for one token (used by opportunistic masking).
    pub fn m1_contains(&self, term: TermId, q: u32, next: TermId, token: usize) -> bool {
        if !self.with_m1 {
            return self.m0_contains(term, q, token);
        }
        let idx = self.m1_tab()[self.gidx(term, q) * self.nterms + next as usize];
        idx != NONE && self.pool_mask(idx).get(token)
    }

    pub fn m0_contains(&self, term: TermId, q: u32, token: usize) -> bool {
        let idx = self.m0_tab()[self.gidx(term, q)];
        idx != NONE && self.pool_mask(idx).get(token)
    }

    /// Build the store for a grammar × tokenizer pair — trie-driven (see
    /// the module docs and `mask/trie.rs`): prefix-sharing walks over the
    /// tokenizer's cached [`TokenTrie`] with static dead-byte pruning and
    /// byte-class projection. Output is bit-identical to
    /// [`MaskStore::build_reference`].
    ///
    /// The per-(state, token) walk loop — the dominant offline cost of
    /// Table 5 — is sharded across `cfg.threads` workers over contiguous
    /// ranges of live DFA states. Shard outputs are merged *in shard
    /// order*, re-interning each shard-local mask pool into the global
    /// pool, so the result (masks, pool order, and serialised bytes) is
    /// bit-identical to the serial build for every thread count.
    pub fn build(g: &Grammar, tok: &Tokenizer, cfg: MaskStoreConfig) -> MaskStore {
        MaskStore::build_impl(g, tok, cfg, true)
    }

    /// The naive per-(state, token) builder: every token walked
    /// byte-by-byte from every live state, no trie, no static filters.
    /// Kept as the oracle [`MaskStore::build`] is asserted bit-identical
    /// against (`rust/tests/trie_parity.rs`) — the two share every line of
    /// mask assembly and differ only in how `walk_info` is produced.
    pub fn build_reference(g: &Grammar, tok: &Tokenizer, cfg: MaskStoreConfig) -> MaskStore {
        MaskStore::build_impl(g, tok, cfg, false)
    }

    fn build_impl(g: &Grammar, tok: &Tokenizer, cfg: MaskStoreConfig, use_trie: bool) -> MaskStore {
        let t0 = std::time::Instant::now();
        let nterms = g.terminals.len();
        let vocab_size = tok.vocab_size();
        let max_token_len = cfg.effective_max_token_len();

        // Global state numbering.
        let mut offsets = Vec::with_capacity(nterms);
        let mut num_states = 0u32;
        for t in &g.terminals {
            offsets.push(num_states);
            num_states += t.dfa.num_states() as u32;
        }

        // Tokens that participate (non-special, non-empty, not too long),
        // in token-id order — `walk_info`/`suff` are indexed by position
        // in this list.
        let tokens = tok.participating_tokens(max_token_len);
        let total_token_bytes: u64 = tokens.iter().map(|&(_, b)| b.len() as u64).sum();

        // The trie is cached on the tokenizer: request-time compiles of
        // other grammars against the same vocabulary reuse it.
        let trie = use_trie.then(|| tok.token_trie(max_token_len));
        debug_assert!(trie
            .as_ref()
            .map(|t| t.token_ids().iter().copied().eq(tokens.iter().map(|&(id, _)| id)))
            .unwrap_or(true));

        // Per-terminal static dead-byte tables (trie mode only).
        let dead: Vec<Vec<bool>> = if trie.is_some() {
            g.terminals
                .iter()
                .map(|t| {
                    if matches!(t.pattern, TermPattern::Declared) {
                        Vec::new()
                    } else {
                        t.dfa.dead_classes()
                    }
                })
                .collect()
        } else {
            Vec::new()
        };

        // ---- pass 1: suffmatch(τ, t, i) -------------------------------
        let suff: Vec<Vec<u128>> = match &trie {
            Some(trie) => g
                .terminals
                .iter()
                .map(|t| {
                    if matches!(t.pattern, TermPattern::Declared) {
                        vec![0u128; tokens.len()] // declared terminals never match text
                    } else {
                        trie.suffix_match(&t.dfa)
                    }
                })
                .collect(),
            None => suffix_match_table(g, &tokens),
        };

        // ---- pass 2: per (state, token) walks; assemble M₀ / M₁ --------
        // Work items: every live state of every lexable terminal, in
        // (terminal, state) order — the serial iteration order.
        let items: Vec<(u16, u32)> = g
            .terminals
            .iter()
            .enumerate()
            .filter(|(_, t)| !matches!(t.pattern, TermPattern::Declared))
            .flat_map(|(ti, t)| {
                (0..t.dfa.num_states() as u32)
                    .filter(move |&q| t.dfa.is_live(q))
                    .map(move |q| (ti as u16, q))
            })
            .collect();

        let threads = match cfg.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
        .min(items.len().max(1));

        let shard = ShardContext {
            g,
            tokens: &tokens,
            suff: &suff,
            offsets: &offsets,
            vocab_size,
            nterms,
            with_m1: cfg.with_m1,
            trie: trie.as_deref(),
            dead: &dead,
        };
        let outs: Vec<ShardOut> = if threads <= 1 {
            vec![shard.process(&items)]
        } else {
            // Contiguous balanced chunks; merge order = chunk order below.
            let chunk = items.len().div_ceil(threads);
            std::thread::scope(|s| {
                let handles: Vec<_> = items
                    .chunks(chunk)
                    .map(|c| {
                        let shard = &shard;
                        s.spawn(move || shard.process(c))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("mask-store build worker panicked"))
                    .collect()
            })
        };

        // ---- ordered merge --------------------------------------------
        let mut interner = Interner::default();
        let mut m0 = vec![NONE; num_states as usize];
        let mut m1 = if cfg.with_m1 {
            vec![NONE; num_states as usize * nterms]
        } else {
            Vec::new()
        };
        let mut walk = TrieWalkStats::default();
        for out in outs {
            walk.merge(&out.walk);
            // Shard-local pool index → global pool index (first-occurrence
            // order is preserved because shards merge in item order).
            let map: Vec<u32> =
                out.pool.into_iter().map(|mask| interner.intern(mask)).collect();
            for (gidx, local) in out.m0 {
                m0[gidx as usize] = map[local as usize];
            }
            for (flat, local) in out.m1 {
                m1[flat] = map[local as usize];
            }
        }
        let words_per = vocab_size.div_ceil(64);
        let unique_masks = interner.pool.len();
        let pool: Vec<u64> = interner
            .pool
            .iter()
            .flat_map(|mask| mask.words().iter().copied())
            .collect();

        let mask_bytes = words_per * 8;
        let mem_bytes = unique_masks * mask_bytes + (m0.len() + m1.len()) * 4;
        let raw_bytes = (m0.len() + m1.len()) * mask_bytes;
        let stats = MaskStoreStats {
            build_secs: t0.elapsed().as_secs_f64(),
            build_threads: threads,
            vocab_size,
            num_dfa_states: num_states as usize,
            num_terminals: nterms,
            unique_masks,
            m0_entries: m0.len(),
            m1_entries: m1.len(),
            mem_bytes,
            raw_bytes,
            zero_copy: false,
            mapped: false,
            walk_steps: walk.steps,
            naive_steps: items.len() as u64 * total_token_bytes,
            trie_nodes_visited: walk.nodes_visited,
            pruned_dead_byte: walk.pruned_dead_byte,
        };

        MaskStore {
            vocab_size,
            eos_id: tok.eos_id,
            num_states: num_states as usize,
            nterms,
            words_per,
            with_m1: cfg.with_m1,
            max_token_len: Some(max_token_len),
            data: StoreData::Owned { offsets, m0, m1, pool },
            stats,
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn num_states(&self) -> usize {
        self.num_states
    }

    // ---- serialisation ------------------------------------------------

    /// Serialise to the current `SYNCMSK2` format (paper §4.3: "we cache
    /// and reuse this table for future inferences"): a fixed u64 header
    /// (dims + `eos_id` + the build-relevant config), then the offsets /
    /// M₀ / M₁ index tables and the interned pool as 8-byte-aligned
    /// little-endian sections, readable in place via
    /// [`MaskStore::from_blob`].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let push64 = |out: &mut Vec<u8>, v: u64| out.extend_from_slice(&v.to_le_bytes());
        out.extend_from_slice(b"SYNCMSK2");
        push64(&mut out, self.vocab_size as u64);
        push64(&mut out, self.eos_id as u64);
        push64(&mut out, self.num_states as u64);
        push64(&mut out, self.nterms as u64);
        push64(&mut out, self.with_m1 as u64);
        // u64::MAX = "not recorded" (store was loaded from a legacy blob).
        push64(&mut out, self.max_token_len.map(|n| n as u64).unwrap_or(u64::MAX));
        push64(&mut out, self.m0_tab().len() as u64);
        push64(&mut out, self.m1_tab().len() as u64);
        push64(&mut out, (self.pool_words().len() / self.words_per.max(1)) as u64);
        for &v in self.offsets() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        pad8(&mut out);
        for &v in self.m0_tab() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        pad8(&mut out);
        for &v in self.m1_tab() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        pad8(&mut out);
        for &w in self.pool_words() {
            push64(&mut out, w);
        }
        out
    }

    /// Serialise to the legacy `SYNCMSK1` format. Kept (a) so the
    /// format-stability tests can assert old blobs still load and (b) as
    /// the reference layout documented in `docs/artifacts.md`.
    pub fn to_bytes_v1(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let push64 = |out: &mut Vec<u8>, v: u64| out.extend_from_slice(&v.to_le_bytes());
        out.extend_from_slice(b"SYNCMSK1");
        push64(&mut out, self.vocab_size as u64);
        push64(&mut out, self.eos_id as u64);
        push64(&mut out, self.num_states as u64);
        push64(&mut out, self.nterms as u64);
        push64(&mut out, self.offsets().len() as u64);
        push64(&mut out, self.m0_tab().len() as u64);
        push64(&mut out, self.m1_tab().len() as u64);
        push64(&mut out, (self.pool_words().len() / self.words_per.max(1)) as u64);
        for &v in self.offsets() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in self.m0_tab() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in self.m1_tab() {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &w in self.pool_words() {
            push64(&mut out, w);
        }
        out
    }

    /// Deserialise a blob written by [`MaskStore::to_bytes`] (`SYNCMSK2`)
    /// or the legacy [`MaskStore::to_bytes_v1`] (`SYNCMSK1`). Always
    /// copies into owned storage; use [`MaskStore::from_blob`] for the
    /// zero-copy path.
    pub fn from_bytes(data: &[u8]) -> Result<MaskStore, String> {
        match data.get(..8) {
            Some(b"SYNCMSK1") => MaskStore::parse_v1(data),
            Some(b"SYNCMSK2") => MaskStore::parse_v2_owned(data),
            _ => Err("bad mask store magic".into()),
        }
    }

    /// Zero-copy load: validate the header and index tables of a
    /// `SYNCMSK2` blob and serve lookups directly from `blob`'s bytes.
    /// Legacy `SYNCMSK1` content falls back to the copying loader, and so
    /// do big-endian hosts (the format is little-endian). A misaligned
    /// `SYNCMSK2` section is an error, never a panic.
    pub fn from_blob(blob: Arc<Blob>) -> Result<MaskStore, String> {
        let len = blob.len();
        MaskStore::from_blob_section(blob, 0, len)
    }

    /// [`MaskStore::from_blob`] for a store embedded inside a larger blob
    /// (the `SYNCART1` artifact): the section at `[off, off+len)` must be
    /// 8-aligned relative to the blob start for the in-place view.
    pub fn from_blob_section(
        blob: Arc<Blob>,
        off: usize,
        len: usize,
    ) -> Result<MaskStore, String> {
        if off.checked_add(len).map(|end| end > blob.len()).unwrap_or(true) {
            return Err("mask store section out of range".into());
        }
        let data = &blob[off..off + len];
        match data.get(..8) {
            // Legacy format: unaligned u32 tables — copy-deserialise.
            Some(b"SYNCMSK1") => MaskStore::parse_v1(data),
            Some(b"SYNCMSK2") => {
                if !Blob::HOST_VIEWABLE {
                    // Big-endian host: the LE sections need byte-swapping,
                    // so zero-copy is impossible — copy-deserialise.
                    return MaskStore::parse_v2_owned(data);
                }
                if off % 8 != 0 {
                    return Err(format!("misaligned mask store section (offset {off})"));
                }
                MaskStore::parse_v2_view(blob.clone(), off, len)
            }
            _ => Err("bad mask store magic".into()),
        }
    }

    /// Parse the `SYNCMSK2` header; returns the dims/config plus the
    /// reader positioned at the start of the offsets section.
    fn parse_v2_header(data: &[u8]) -> Result<(V2Header, BlobReader<'_>), String> {
        let mut r = BlobReader::new(data);
        if r.take(8)? != b"SYNCMSK2" {
            return Err("bad mask store magic".into());
        }
        let vocab_size = r.len_field()?;
        let eos_id = r.u64()? as u32;
        let num_states = r.len_field()?;
        let nterms = r.len_field()?;
        let with_m1 = match r.u64()? {
            0 => false,
            1 => true,
            other => return Err(format!("bad with_m1 flag {other}")),
        };
        let max_token_len = match r.u64()? {
            u64::MAX => None,
            n => Some(usize::try_from(n).map_err(|_| "oversized max_token_len")?),
        };
        let n_m0 = r.len_field()?;
        let n_m1 = r.len_field()?;
        let n_pool = r.len_field()?;
        let header = V2Header {
            vocab_size,
            eos_id,
            num_states,
            nterms,
            with_m1,
            max_token_len,
            n_m0,
            n_m1,
            n_pool,
        };
        Ok((header, r))
    }

    fn parse_v2_owned(data: &[u8]) -> Result<MaskStore, String> {
        let (h, mut r) = MaskStore::parse_v2_header(data)?;
        let offsets = r.u32s(h.nterms)?;
        r.align8()?;
        let m0 = r.u32s(h.n_m0)?;
        r.align8()?;
        let m1 = r.u32s(h.n_m1)?;
        r.align8()?;
        let words_per = h.vocab_size.div_ceil(64);
        let pool_words =
            h.n_pool.checked_mul(words_per).ok_or("oversized mask pool")?;
        let pool = r.u64s(pool_words)?;
        if !r.at_end() {
            return Err("trailing bytes after mask store".into());
        }
        h.validate(&offsets, &m0, &m1)?;
        Ok(h.into_store(StoreData::Owned { offsets, m0, m1, pool }, false, false))
    }

    fn parse_v2_view(blob: Arc<Blob>, off: usize, len: usize) -> Result<MaskStore, String> {
        let data = &blob[off..off + len];
        let (h, mut r) = MaskStore::parse_v2_header(data)?;
        // Walk the sections with the reader (bounds + zero-padding checks),
        // recording each section's absolute offset for the in-place views.
        let words_per = h.vocab_size.div_ceil(64);
        let sec = |r: &mut BlobReader<'_>, elems: usize, size: usize| -> Result<usize, String> {
            let start = off + r.pos();
            r.take(elems.checked_mul(size).ok_or("oversized table")?)?;
            Ok(start)
        };
        let offsets = Sect { off: sec(&mut r, h.nterms, 4)?, len: h.nterms };
        r.align8()?;
        let m0 = Sect { off: sec(&mut r, h.n_m0, 4)?, len: h.n_m0 };
        r.align8()?;
        let m1 = Sect { off: sec(&mut r, h.n_m1, 4)?, len: h.n_m1 };
        r.align8()?;
        let pool_words =
            h.n_pool.checked_mul(words_per).ok_or("oversized mask pool")?;
        let pool = Sect { off: sec(&mut r, pool_words, 8)?, len: pool_words };
        if !r.at_end() {
            return Err("trailing bytes after mask store".into());
        }
        // Materialise the views once to validate indices (and alignment:
        // section offsets are 8-aligned by construction, but a hostile
        // header could still make Blob::u32s refuse — treat as corrupt).
        let off_v = blob.u32s(offsets.off, offsets.len).ok_or("misaligned offsets section")?;
        let m0_v = blob.u32s(m0.off, m0.len).ok_or("misaligned m0 section")?;
        let m1_v = blob.u32s(m1.off, m1.len).ok_or("misaligned m1 section")?;
        blob.u64s(pool.off, pool.len).ok_or("misaligned pool section")?;
        h.validate(off_v, m0_v, m1_v)?;
        let mapped = blob.is_mapped();
        let data = StoreData::View { blob: blob.clone(), offsets, m0, m1, pool };
        Ok(h.into_store(data, true, mapped))
    }

    fn parse_v1(data: &[u8]) -> Result<MaskStore, String> {
        let mut r = BlobReader::new(data);
        if r.take(8)? != b"SYNCMSK1" {
            return Err("bad mask store magic".into());
        }
        let vocab_size = r.len_field()?;
        let eos_id = r.u64()? as u32;
        let num_states = r.len_field()?;
        let nterms = r.len_field()?;
        let n_off = r.len_field()?;
        let n_m0 = r.len_field()?;
        let n_m1 = r.len_field()?;
        let n_pool = r.len_field()?;
        let offsets = r.u32s(n_off)?;
        let m0 = r.u32s(n_m0)?;
        let m1 = r.u32s(n_m1)?;
        let words_per = vocab_size.div_ceil(64);
        let pool_words = n_pool.checked_mul(words_per).ok_or("oversized mask pool")?;
        let pool = r.u64s(pool_words)?;
        let h = V2Header {
            vocab_size,
            eos_id,
            num_states,
            nterms,
            // Legacy blobs record neither flag; M₁ presence is inferable
            // from the table, the length cap is simply unknown.
            with_m1: !m1.is_empty(),
            max_token_len: None,
            n_m0,
            n_m1,
            n_pool,
        };
        h.validate(&offsets, &m0, &m1)?;
        Ok(h.into_store(StoreData::Owned { offsets, m0, m1, pool }, false, false))
    }

    /// Does a deserialised store match the (grammar, tokenizer, config)
    /// triple a caller wants to serve? This is the cache-validation
    /// predicate of [`MaskStore::load_or_build`]: the grammar's shape
    /// (terminal count + total DFA states — a store built for a different
    /// grammar would index out of range or serve unsound masks),
    /// vocabulary size, EOS id and the build-relevant config fields must
    /// all agree. Legacy `SYNCMSK1` blobs never recorded `max_token_len`,
    /// so only the inferable fields are checked for them (see
    /// `docs/artifacts.md`).
    pub fn matches(&self, g: &Grammar, tok: &Tokenizer, cfg: &MaskStoreConfig) -> bool {
        self.nterms == g.terminals.len()
            && self.num_states == g.total_dfa_states()
            && self.vocab_size == tok.vocab_size()
            && self.eos_id == tok.eos_id
            && self.with_m1 == cfg.with_m1
            && self
                .max_token_len
                .map(|n| n == cfg.effective_max_token_len())
                .unwrap_or(true)
    }

    /// Load from `path` when present and matching (vocab, EOS, config —
    /// see [`MaskStore::matches`]), else build and cache there. The load
    /// maps the file (zero-copy on unix); a stale or corrupt cache falls
    /// through to a rebuild that overwrites it in the current format.
    pub fn load_or_build(
        path: &std::path::Path,
        g: &Grammar,
        tok: &Tokenizer,
        cfg: MaskStoreConfig,
    ) -> MaskStore {
        if let Ok(blob) = Blob::from_file(path) {
            if let Ok(s) = MaskStore::from_blob(Arc::new(blob)) {
                if s.matches(g, tok, &cfg) {
                    return s;
                }
            }
        }
        let s = MaskStore::build(g, tok, cfg);
        // Atomic replace: another process may be serving from a mapping
        // of the stale file — an in-place write would truncate under it.
        let _ = crate::util::blob::write_atomic(path, &s.to_bytes());
        s
    }
}

/// Parsed `SYNCMSK2` header (also the common denominator `SYNCMSK1`
/// parses into).
struct V2Header {
    vocab_size: usize,
    eos_id: u32,
    num_states: usize,
    nterms: usize,
    with_m1: bool,
    max_token_len: Option<usize>,
    n_m0: usize,
    n_m1: usize,
    n_pool: usize,
}

impl V2Header {
    /// Structural validation shared by every deserialisation path. The
    /// blob is untrusted (a cache file): every index a lookup can follow
    /// must be in range, or serving would panic instead of falling back
    /// to a rebuild.
    fn validate(&self, offsets: &[u32], m0: &[u32], m1: &[u32]) -> Result<(), String> {
        if self.vocab_size == 0 || (self.eos_id as usize) >= self.vocab_size {
            return Err("eos id outside vocabulary".into());
        }
        if offsets.len() != self.nterms {
            return Err("offsets/terminal count mismatch".into());
        }
        if m0.len() != self.num_states {
            return Err("m0/state count mismatch".into());
        }
        let m1_expect = self
            .num_states
            .checked_mul(self.nterms)
            .ok_or("oversized m1 dimensions")?;
        if self.with_m1 && m1.len() != m1_expect {
            return Err("m1/state×terminal count mismatch".into());
        }
        if !self.with_m1 && !m1.is_empty() {
            return Err("m1 table present but with_m1 unset".into());
        }
        if offsets.iter().any(|&o| o as usize > self.num_states) {
            return Err("terminal offset out of range".into());
        }
        let pool_len = u32::try_from(self.n_pool).map_err(|_| "oversized pool")?;
        if m0.iter().chain(m1.iter()).any(|&v| v != NONE && v >= pool_len) {
            return Err("mask pool index out of range".into());
        }
        Ok(())
    }

    fn into_store(self, data: StoreData, zero_copy: bool, mapped: bool) -> MaskStore {
        let words_per = self.vocab_size.div_ceil(64);
        let mask_bytes = words_per * 8;
        let mem_bytes = self.n_pool * mask_bytes + (self.n_m0 + self.n_m1) * 4;
        let raw_bytes = (self.n_m0 + self.n_m1) * mask_bytes;
        MaskStore {
            vocab_size: self.vocab_size,
            eos_id: self.eos_id,
            num_states: self.num_states,
            nterms: self.nterms,
            words_per,
            with_m1: self.with_m1,
            max_token_len: self.max_token_len,
            data,
            stats: MaskStoreStats {
                build_secs: 0.0,
                build_threads: 0,
                vocab_size: self.vocab_size,
                num_dfa_states: self.num_states,
                num_terminals: self.nterms,
                unique_masks: self.n_pool,
                m0_entries: self.n_m0,
                m1_entries: self.n_m1,
                mem_bytes,
                raw_bytes,
                zero_copy,
                mapped,
                // A loaded store executed no walks — counters are
                // build-time only and not serialised.
                walk_steps: 0,
                naive_steps: 0,
                trie_nodes_visited: 0,
                pruned_dead_byte: 0,
            },
        }
    }
}

/// Hash-deduplicating mask interner (first-occurrence pool order).
#[derive(Default)]
struct Interner {
    pool: Vec<BitSet>,
    /// hash → candidate pool indices (collision chain).
    index: HashMap<u64, Vec<u32>>,
}

impl Interner {
    fn intern(&mut self, mask: BitSet) -> u32 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        mask.hash(&mut h);
        let key = h.finish();
        let cands = self.index.entry(key).or_default();
        for &c in cands.iter() {
            if self.pool[c as usize] == mask {
                return c;
            }
        }
        let id = self.pool.len() as u32;
        self.pool.push(mask);
        cands.push(id);
        id
    }
}

/// Bits 0..n (exclusive) — the "strictly before position n" mask.
/// `n` must be ≤ [`MaskStoreConfig::MAX_SPLIT_LEN`].
#[inline]
fn mask_below(n: usize) -> u128 {
    (1u128 << n) - 1
}

/// One token walked byte-by-byte from `q` — the single shared walker
/// behind the reference builder, the naive suffix table and the
/// brute-force tests, so none of them can drift from each other (or from
/// the trie DFS they cross-check).
pub(crate) struct TokenWalk {
    /// The walk survived every byte *and* landed in a live state
    /// (Definition 10 condition 1).
    pub live_all: bool,
    /// Bit `i` ⇔ the `i`-byte prefix sits in a final state (split points
    /// of conditions 2/3; positions above
    /// [`MaskStoreConfig::MAX_SPLIT_LEN`] are dropped).
    pub fhits: u128,
    /// `dfa.step` calls executed (walks stop at `DEAD`).
    pub steps: u64,
}

pub(crate) fn walk_token(dfa: &Dfa, q: u32, bytes: &[u8]) -> TokenWalk {
    let mut cur = q;
    let mut fhits = if dfa.is_accept(cur) { 1u128 } else { 0 };
    let mut live_all = true;
    let mut steps = 0u64;
    for (j, &b) in bytes.iter().enumerate() {
        steps += 1;
        cur = dfa.step(cur, b);
        if cur == DEAD {
            live_all = false;
            break;
        }
        if dfa.is_accept(cur) && j + 1 <= MaskStoreConfig::MAX_SPLIT_LEN {
            fhits |= 1 << (j + 1);
        }
    }
    if live_all && !dfa.is_live(cur) {
        live_all = false;
    }
    TokenWalk { live_all, fhits, steps }
}

/// Pass 1 (naive reference): suff[τ][k] = bitmask over suffix starts i
/// (bit i set ⇔ dmatch(t[i..], q0^τ, {})), for token index k — the "jump
/// into the next terminal" primitive of Definition 10 condition 3. The
/// trie build computes the same table via [`TokenTrie::suffix_match`].
///
/// Split bitmasks are 128-bit: a token of up to
/// [`MaskStoreConfig::MAX_SPLIT_LEN`] bytes keeps every suffix-start
/// position 0..=len, including the final one (positions beyond the u64
/// range used to be silently dropped — a completeness loss for 64-byte
/// tokens under the default cap).
fn suffix_match_table(g: &Grammar, tokens: &[(u32, &[u8])]) -> Vec<Vec<u128>> {
    let mut suff: Vec<Vec<u128>> = vec![vec![0u128; tokens.len()]; g.terminals.len()];
    for (term_idx, term) in g.terminals.iter().enumerate() {
        if matches!(term.pattern, TermPattern::Declared) {
            continue; // declared terminals never match text
        }
        let dfa = &term.dfa;
        let suffv = &mut suff[term_idx];
        for (k, &(_, bytes)) in tokens.iter().enumerate() {
            let n = bytes.len().min(MaskStoreConfig::MAX_SPLIT_LEN);
            let mut bits = 0u128;
            for i in 0..=n {
                // dmatch(t[i..], q0, {}): the whole suffix stays live —
                // condition 1, and dmatch(ε) = live(q0) for i = len — OR
                // an F state strictly inside the suffix (condition 2;
                // strictly, because the leftover must be nonempty).
                let w = walk_token(dfa, dfa.start(), &bytes[i..]);
                if w.live_all || w.fhits & mask_below(bytes.len() - i) != 0 {
                    bits |= 1 << i;
                }
            }
            suffv[k] = bits;
        }
    }
    suff
}

/// Read-only inputs shared by every build shard.
struct ShardContext<'a> {
    g: &'a Grammar,
    tokens: &'a [(u32, &'a [u8])],
    suff: &'a [Vec<u128>],
    offsets: &'a [u32],
    vocab_size: usize,
    nterms: usize,
    with_m1: bool,
    /// `Some` for the trie build, `None` for the naive reference.
    trie: Option<&'a TokenTrie>,
    /// Per-terminal [`Dfa::dead_classes`] tables (empty in reference mode
    /// and for declared terminals).
    dead: &'a [Vec<bool>],
}

/// One shard's output: sparse (index, local-pool-id) entries plus the
/// shard-local interned pool. Empty masks are simply absent (NONE).
struct ShardOut {
    pool: Vec<BitSet>,
    /// (global state index, local pool id)
    m0: Vec<(u32, u32)>,
    /// (flat m1 index = gidx * nterms + next, local pool id)
    m1: Vec<(usize, u32)>,
    /// Walk-cost counters, merged into `MaskStoreStats`.
    walk: TrieWalkStats,
}

impl ShardContext<'_> {
    /// Walk every token from every (terminal, state) item and assemble the
    /// shard's M₀/M₁ entries — the body of the paper's offline loop.
    ///
    /// `walk_info` is indexed by token, so the trie DFS and the naive
    /// per-token loop fill identical tables and everything downstream
    /// (mask assembly, interning, pool order) is shared verbatim — the
    /// crux of the bit-identical-output guarantee.
    fn process(&self, items: &[(u16, u32)]) -> ShardOut {
        let mut interner = Interner::default();
        let mut out = ShardOut {
            pool: Vec::new(),
            m0: Vec::new(),
            m1: Vec::new(),
            walk: TrieWalkStats::default(),
        };
        // Reusable per-token scratch: (live_all, fhits bitmask incl. bit len).
        let mut walk_info: Vec<(bool, u128)> = vec![(false, 0); self.tokens.len()];
        let mut scratch = TrieScratch::default();

        for &(term_idx, q) in items {
            let dfa = &self.g.terminals[term_idx as usize].dfa;
            // Walk every token from q.
            match self.trie {
                Some(trie) => trie.walk_masks(
                    dfa,
                    q,
                    &self.dead[term_idx as usize],
                    &mut walk_info,
                    &mut scratch,
                    &mut out.walk,
                ),
                None => {
                    for (k, &(_, bytes)) in self.tokens.iter().enumerate() {
                        let w = walk_token(dfa, q, bytes);
                        out.walk.steps += w.steps;
                        walk_info[k] = (w.live_all, w.fhits);
                    }
                }
            }

            // M₀(q): live_all OR a strict-prefix F hit.
            let mut mask = BitSet::new(self.vocab_size);
            for (k, &(id, bytes)) in self.tokens.iter().enumerate() {
                let (live_all, fhits) = walk_info[k];
                let strict = fhits & mask_below(bytes.len().min(MaskStoreConfig::MAX_SPLIT_LEN));
                if live_all || strict != 0 {
                    mask.set(id as usize);
                }
            }
            let g_idx = (self.offsets[term_idx as usize] + q) as usize;
            if !mask.is_empty() {
                out.m0.push((g_idx as u32, interner.intern(mask)));
            }

            // M₁(q, τnext): live_all OR some F-hit position i with
            // suffmatch(τnext, t, i).
            if self.with_m1 {
                for nt in 0..self.nterms {
                    if matches!(
                        self.g.terminals[nt].pattern,
                        TermPattern::Declared
                    ) {
                        continue;
                    }
                    let mut mask = BitSet::new(self.vocab_size);
                    let suffv = &self.suff[nt];
                    for (k, &(id, _)) in self.tokens.iter().enumerate() {
                        let (live_all, fhits) = walk_info[k];
                        if live_all || (fhits & suffv[k]) != 0 {
                            mask.set(id as usize);
                        }
                    }
                    if !mask.is_empty() {
                        out.m1.push((g_idx * self.nterms + nt, interner.intern(mask)));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::Grammar;

    fn store_for(name: &str, merges: usize) -> (Grammar, Tokenizer, MaskStore) {
        let g = Grammar::builtin(name).unwrap();
        let corpus: Vec<u8> = match name {
            "json" => br#"{"alpha": [1, 2.5, true], "beta": {"s": "x"}, "g": null}"#
                .repeat(40)
                .to_vec(),
            _ => b"math_sqrt(3) * (2.27) + 14 / math_sin(30)".repeat(40).to_vec(),
        };
        let t = Tokenizer::train(&corpus, merges);
        let s = MaskStore::build(&g, &t, MaskStoreConfig::default());
        (g, t, s)
    }

    /// Every (m0, m1) lookup two stores can answer must agree.
    fn assert_lookups_agree(g: &Grammar, vocab: usize, a: &MaskStore, b: &MaskStore, tag: &str) {
        for (ti, term) in g.terminals.iter().enumerate() {
            if matches!(term.pattern, crate::grammar::TermPattern::Declared) {
                continue;
            }
            let dfa = &term.dfa;
            for q in 0..dfa.num_states() as u32 {
                if !dfa.is_live(q) {
                    continue;
                }
                for id in (0..vocab).step_by(3) {
                    assert_eq!(
                        a.m0_contains(ti as TermId, q, id),
                        b.m0_contains(ti as TermId, q, id),
                        "{tag}: m0 term {ti} state {q} token {id}"
                    );
                }
                for nt in (0..g.terminals.len()).step_by(2) {
                    for id in (0..vocab).step_by(7) {
                        assert_eq!(
                            a.m1_contains(ti as TermId, q, nt as TermId, id),
                            b.m1_contains(ti as TermId, q, nt as TermId, id),
                            "{tag}: m1 term {ti} state {q} next {nt} token {id}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn m0_prefix_acceptance_is_conservative() {
        // From a FINAL state of INT, every token is in M₀ (Definition 8's
        // prefix case) — the paper's deliberate over-approximation.
        let (g, t, s) = store_for("calc", 0);
        let int = g.term_id("INT").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        let qf = dfa.walk(dfa.start(), b"4");
        assert!(dfa.is_accept(qf));
        let mut m = BitSet::new(t.vocab_size());
        s.union_m0(int, qf, &mut m);
        // digits extend; '(' is a prefix-split; both allowed
        assert!(m.get(b'5' as usize));
        assert!(m.get(b'(' as usize));
    }

    #[test]
    fn m0_from_start_requires_match_prefix() {
        let (g, t, s) = store_for("calc", 0);
        let int = g.term_id("INT").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        let mut m = BitSet::new(t.vocab_size());
        s.union_m0(int, dfa.start(), &mut m);
        assert!(m.get(b'7' as usize));
        assert!(!m.get(b'x' as usize));
        assert!(!m.get(b'+' as usize));
    }

    #[test]
    fn m1_condition3_jump() {
        // M₁(q0_INT, RPAR): token "3)" walks INT to F then ")" starts RPAR.
        let (g, t, s) = store_for("calc", 50);
        let int = g.term_id("INT").unwrap();
        let rpar = g.term_id("RPAR").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        // find a multibyte token like "3)" if trained, else test byte ")"
        // via a digit-state.
        let q1 = dfa.walk(dfa.start(), b"3");
        let mut m = BitSet::new(t.vocab_size());
        s.union_m1(int, q1, rpar, &mut m);
        assert!(m.get(b')' as usize), "')' completes INT and matches RPAR");
        assert!(m.get(b'1' as usize), "digit keeps INT live");
        assert!(!m.get(b'x' as usize));
    }

    #[test]
    fn interning_dedups() {
        let (_, _, s) = store_for("json", 30);
        assert!(s.stats.unique_masks < s.stats.m0_entries + s.stats.m1_entries);
        assert!(s.stats.mem_bytes < s.stats.raw_bytes);
    }

    #[test]
    fn contains_agrees_with_union() {
        let (g, t, s) = store_for("json", 30);
        let string = g.term_id("STRING").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        let q = dfa.walk(dfa.start(), b"\"ab");
        let ws = g.term_id("WS").unwrap();
        let mut m = BitSet::new(t.vocab_size());
        s.union_m1(string, q, ws, &mut m);
        for id in 0..t.vocab_size() {
            assert_eq!(m.get(id), s.m1_contains(string, q, ws, id), "token {id}");
        }
    }

    #[test]
    fn m1_brute_force_agreement() {
        // Cross-check the assembled M₁ against a direct recursive dmatch
        // implementation on a byte-level vocabulary. Conditions 1–3 read
        // off one `walk_token` call: `live_all` is condition 1, an `fhits`
        // bit at i means the prefix t[..i] sits in F (the split point of
        // conditions 2/3).
        let (g, t, s) = store_for("calc", 0);
        fn dmatch(
            g: &Grammar,
            term: TermId,
            q: u32,
            bytes: &[u8],
            lam: &[TermId],
        ) -> bool {
            let dfa = &g.terminals[term as usize].dfa;
            let w = walk_token(dfa, q, bytes);
            if w.live_all {
                return true; // condition 1
            }
            for i in 0..=bytes.len() {
                if w.fhits & (1u128 << i) == 0 {
                    continue; // prefix t[..i] not in F (or walk died first)
                }
                let w2 = &bytes[i..];
                match lam.split_first() {
                    None => {
                        if !w2.is_empty() {
                            return true; // condition 2
                        }
                    }
                    Some((&nxt, rest)) => {
                        let ndfa = &g.terminals[nxt as usize].dfa;
                        if dmatch(g, nxt, ndfa.start(), w2, rest) {
                            return true; // condition 3
                        }
                    }
                }
            }
            false
        }
        let int = g.term_id("INT").unwrap();
        let plus = g.term_id("PLUS").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        for probe in [b"1".as_slice(), b"12", b""] {
            let q = dfa.walk(dfa.start(), probe);
            if !dfa.is_live(q) {
                continue;
            }
            for id in 0..256u32 {
                let bytes = t.token_bytes(id).to_vec();
                if bytes.is_empty() {
                    continue;
                }
                let expect = dmatch(&g, int, q, &bytes, &[plus]);
                assert_eq!(
                    s.m1_contains(int, q, plus, id as usize),
                    expect,
                    "token {:?} from r={:?}",
                    bytes,
                    probe
                );
            }
        }
    }

    #[test]
    fn suffix_split_survives_64_byte_token() {
        // Regression (ISSUE 4 satellite): split-position bitmasks used to
        // be u64, so a 64-byte token's *final* split point (position 63)
        // fell off the mask and the token was silently dropped from M₀ —
        // a completeness loss at exactly the default max_token_len.
        //
        // Token: `"` + 61×`a` + `"` + `x` (64 bytes). From JSON STRING's
        // start state the only F-hit is after the closing quote at
        // position 63; byte 64 (`x`) kills the DFA. Condition 2 (prefix
        // in F, nonempty leftover) therefore holds only via that final
        // split point.
        let g = Grammar::builtin("json").unwrap();
        let mut merges: Vec<(u32, u32)> = vec![(b'"' as u32, b'a' as u32)];
        let mut last = 256u32;
        for _ in 0..60 {
            merges.push((last, b'a' as u32));
            last += 1;
        }
        merges.push((last, b'"' as u32));
        last += 1;
        let quoted = last; // `"a…a"` — 63 bytes
        merges.push((quoted, b'x' as u32));
        last += 1;
        let token = last; // 64 bytes, split point only at 63
        let tok = Tokenizer::from_merges(&merges);
        assert_eq!(tok.token_bytes(token).len(), 64);
        let cfg = MaskStoreConfig::default();
        assert_eq!(cfg.max_token_len, 64, "regression targets the default cap");
        let s = MaskStore::build(&g, &tok, cfg);
        let string = g.term_id("STRING").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        assert!(
            s.m0_contains(string, dfa.start(), token as usize),
            "64-byte token with only a final split point must stay in M₀"
        );
        // Sanity: the 63-byte complete string is in via live_all/accept …
        assert!(s.m0_contains(string, dfa.start(), quoted as usize));
        // … and a token that dies immediately is NOT over-approximated in.
        assert!(!s.m0_contains(string, dfa.start(), b'x' as usize));
    }

    #[test]
    fn serialisation_roundtrip() {
        let (g, t, s) = store_for("json", 40);
        let blob = s.to_bytes();
        let s2 = MaskStore::from_bytes(&blob).unwrap();
        assert_eq!(s.vocab_size(), s2.vocab_size());
        assert_eq!(s.num_states(), s2.num_states());
        assert_eq!(s2.with_m1(), s.with_m1());
        assert_eq!(s2.max_token_len(), s.max_token_len());
        // Re-serialisation is byte-identical (format is canonical).
        assert_eq!(s2.to_bytes(), blob);
        // Every lookup agrees.
        let string = g.term_id("STRING").unwrap();
        let ws = g.term_id("WS").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        for probe in [b"\"a".as_slice(), b"\"xy", b"\""] {
            let q = dfa.walk(dfa.start(), probe);
            for id in 0..t.vocab_size() {
                assert_eq!(
                    s.m0_contains(string, q, id),
                    s2.m0_contains(string, q, id)
                );
                assert_eq!(
                    s.m1_contains(string, q, ws, id),
                    s2.m1_contains(string, q, ws, id)
                );
            }
        }
    }

    #[test]
    fn legacy_v1_blob_still_loads() {
        // Format-stability: a blob in the original SYNCMSK1 layout loads
        // and answers every lookup identically to the live store.
        let (g, t, s) = store_for("json", 40);
        let legacy = s.to_bytes_v1();
        assert_eq!(&legacy[..8], b"SYNCMSK1");
        let s1 = MaskStore::from_bytes(&legacy).unwrap();
        assert!(!s1.stats.zero_copy);
        assert_eq!(s1.max_token_len(), None, "v1 never recorded the cap");
        assert_eq!(s1.with_m1(), s.with_m1());
        assert_lookups_agree(&g, t.vocab_size(), &s, &s1, "v1");
        // And it upgrades: re-serialising writes the current format.
        assert_eq!(&s1.to_bytes()[..8], b"SYNCMSK2");
    }

    #[test]
    fn mapped_view_agrees_with_owned_on_every_lookup() {
        let (g, t, s) = store_for("json", 40);
        let blob = Arc::new(Blob::from_vec(s.to_bytes()));
        let view = MaskStore::from_blob(blob).unwrap();
        if Blob::HOST_VIEWABLE {
            assert!(view.stats.zero_copy, "aligned SYNCMSK2 blob must load in place");
            assert!(!view.stats.mapped, "an owned in-memory blob is not a mapping");
        }
        assert_lookups_agree(&g, t.vocab_size(), &s, &view, "view");
        // union_* through the view matches the owned store bit-for-bit.
        let string = g.term_id("STRING").unwrap();
        let ws = g.term_id("WS").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        let q = dfa.walk(dfa.start(), b"\"ab");
        let mut a = BitSet::new(t.vocab_size());
        let mut b = BitSet::new(t.vocab_size());
        s.union_m1(string, q, ws, &mut a);
        view.union_m1(string, q, ws, &mut b);
        assert_eq!(a, b);
        // View serialises back to the identical bytes.
        assert_eq!(view.to_bytes(), s.to_bytes());
    }

    #[test]
    fn truncated_and_misaligned_v2_error_not_panic() {
        let (_, _, s) = store_for("calc", 10);
        let bytes = s.to_bytes();
        // Truncations at several depths: header, tables, pool.
        for cut in [4usize, 9, 79, 81, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                MaskStore::from_bytes(&bytes[..cut.min(bytes.len())]).is_err(),
                "cut at {cut} must error"
            );
            let blob = Arc::new(Blob::from_vec(bytes[..cut.min(bytes.len())].to_vec()));
            assert!(MaskStore::from_blob(blob).is_err(), "blob cut at {cut} must error");
        }
        // Trailing garbage is rejected.
        let mut padded = bytes.clone();
        padded.extend_from_slice(b"zz");
        assert!(MaskStore::from_bytes(&padded).is_err());
        // A misaligned section offset inside a blob errors cleanly.
        let mut shifted = vec![0u8; 4];
        shifted.extend_from_slice(&bytes);
        let blob = Arc::new(Blob::from_vec(shifted));
        let r = MaskStore::from_blob_section(blob, 4, bytes.len());
        if Blob::HOST_VIEWABLE {
            assert!(r.is_err(), "misaligned SYNCMSK2 section must error");
        }
        // Out-of-range section is an error, not a slice panic.
        let blob = Arc::new(Blob::from_vec(bytes.clone()));
        assert!(MaskStore::from_blob_section(blob, 8, usize::MAX).is_err());
    }

    #[test]
    fn from_bytes_rejects_garbage() {
        assert!(MaskStore::from_bytes(b"nope").is_err());
        assert!(MaskStore::from_bytes(b"SYNCMSK1short").is_err());
        assert!(MaskStore::from_bytes(b"SYNCMSK2short").is_err());
    }

    #[test]
    fn load_or_build_caches_zero_copy() {
        let (g, t, _) = store_for("calc", 10);
        let path = std::env::temp_dir().join("syncode_store_test");
        let _ = std::fs::remove_file(&path);
        let s1 = MaskStore::load_or_build(&path, &g, &t, MaskStoreConfig::default());
        assert!(path.exists());
        let s2 = MaskStore::load_or_build(&path, &g, &t, MaskStoreConfig::default());
        assert_eq!(s1.stats.unique_masks, s2.stats.unique_masks);
        assert_eq!(s2.stats.build_secs, 0.0); // loaded, not rebuilt
        assert_eq!(s2.stats.build_threads, 0);
        if Blob::HOST_VIEWABLE && cfg!(unix) {
            assert!(s2.stats.zero_copy, "warm load must serve the cache in place");
            assert!(s2.stats.mapped, "unix warm load must come from an mmap");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_or_build_rejects_stale_config_and_eos() {
        // A cache built with M₁ must not satisfy a with_m1=false request
        // (and vice versa) — build_threads>0 proves a rebuild happened.
        let (g, t, _) = store_for("calc", 10);
        let path = std::env::temp_dir().join("syncode_store_cfgtest");
        let _ = std::fs::remove_file(&path);
        let _ = MaskStore::load_or_build(&path, &g, &t, MaskStoreConfig::default());
        let no_m1 = MaskStoreConfig { with_m1: false, ..MaskStoreConfig::default() };
        let s = MaskStore::load_or_build(&path, &g, &t, no_m1.clone());
        assert_eq!(s.stats.build_threads, 1, "with_m1 change must rebuild");
        assert!(!s.with_m1());
        // Cache now holds the no-m1 store; same config warm-loads it …
        let s = MaskStore::load_or_build(&path, &g, &t, no_m1);
        assert_eq!(s.stats.build_threads, 0);
        // … a different max_token_len rebuilds …
        let short =
            MaskStoreConfig { with_m1: false, max_token_len: 8, ..MaskStoreConfig::default() };
        let s = MaskStore::load_or_build(&path, &g, &t, short);
        assert_eq!(s.stats.build_threads, 1, "max_token_len change must rebuild");
        // … and a tampered eos_id in the header invalidates the cache.
        let mut bytes = std::fs::read(&path).unwrap();
        // id 0 is a valid token but never the EOS id (specials are last).
        bytes[16..24].copy_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let s = MaskStore::load_or_build(
            &path,
            &g,
            &t,
            MaskStoreConfig { with_m1: false, max_token_len: 8, ..MaskStoreConfig::default() },
        );
        assert_eq!(s.stats.build_threads, 1, "eos mismatch must rebuild");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_or_build_rejects_another_grammars_cache() {
        // Same tokenizer + config, different grammar: the cached store's
        // terminal/state shape cannot serve the new grammar (indexing
        // with its terminal ids would panic or return unsound masks), so
        // the cache must be rejected and rebuilt.
        let g_calc = Grammar::builtin("calc").unwrap();
        let g_json = Grammar::builtin("json").unwrap();
        let t = Tokenizer::ascii_byte_level();
        let path = std::env::temp_dir().join("syncode_store_xgram_test");
        let _ = std::fs::remove_file(&path);
        let _ = MaskStore::load_or_build(&path, &g_calc, &t, MaskStoreConfig::default());
        let s = MaskStore::load_or_build(&path, &g_json, &t, MaskStoreConfig::default());
        assert_eq!(s.stats.build_threads, 1, "grammar change must rebuild");
        assert_eq!(s.num_states(), g_json.total_dfa_states());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn effective_cap_clamps_at_split_mask_width() {
        let cfg = MaskStoreConfig { max_token_len: 4096, ..MaskStoreConfig::default() };
        assert_eq!(cfg.effective_max_token_len(), MaskStoreConfig::MAX_SPLIT_LEN);
        let cfg = MaskStoreConfig::default();
        assert_eq!(cfg.effective_max_token_len(), 64);
    }

    #[test]
    fn stats_populated() {
        let (_, _, s) = store_for("calc", 20);
        assert!(s.stats.build_secs >= 0.0);
        assert!(s.stats.num_dfa_states > 10);
        assert!(s.stats.mem_bytes > 0);
        assert_eq!(s.stats.build_threads, 1);
        assert!(!s.stats.zero_copy);
    }

    #[test]
    fn parallel_build_bit_identical_to_serial() {
        // The sharded build must agree with the serial one not just on
        // every mask lookup but on the serialised bytes (pool order is
        // first-occurrence order regardless of thread count).
        let g = Grammar::builtin("json").unwrap();
        let corpus = br#"{"alpha": [1, 2.5, true], "beta": {"s": "x"}}"#.repeat(40);
        let t = Tokenizer::train(&corpus, 40);
        let serial = MaskStore::build(&g, &t, MaskStoreConfig::default());
        for threads in [2usize, 3, 8] {
            let cfg = MaskStoreConfig { threads, ..MaskStoreConfig::default() };
            let par = MaskStore::build(&g, &t, cfg);
            assert_eq!(
                serial.to_bytes(),
                par.to_bytes(),
                "parallel ({threads} threads) differs from serial"
            );
        }
    }

    #[test]
    fn parallel_build_without_m1() {
        let g = Grammar::builtin("calc").unwrap();
        let t = Tokenizer::ascii_byte_level();
        let cfg_s = MaskStoreConfig { with_m1: false, ..MaskStoreConfig::default() };
        let cfg_p = MaskStoreConfig { with_m1: false, threads: 4, ..MaskStoreConfig::default() };
        let serial = MaskStore::build(&g, &t, cfg_s);
        let par = MaskStore::build(&g, &t, cfg_p);
        assert_eq!(serial.to_bytes(), par.to_bytes());
    }

    #[test]
    fn trie_build_matches_reference_quick() {
        // Fast in-crate parity check (the exhaustive five-grammar ×
        // thread-count matrix lives in rust/tests/trie_parity.rs).
        for name in ["calc", "json"] {
            let g = Grammar::builtin(name).unwrap();
            let corpus = br#"{"k": [1, 2.5], "s": "ab"} (3) + 4.5"#.repeat(30);
            let t = Tokenizer::train(&corpus, 48);
            let trie = MaskStore::build(&g, &t, MaskStoreConfig::default());
            let reference = MaskStore::build_reference(&g, &t, MaskStoreConfig::default());
            assert_eq!(trie.to_bytes(), reference.to_bytes(), "{name}: SYNCMSK2 differs");
            assert_eq!(trie.to_bytes_v1(), reference.to_bytes_v1(), "{name}: SYNCMSK1 differs");
        }
    }

    #[test]
    fn trie_suffix_match_equals_naive_table() {
        // The pass-1 tables are compared directly, not just through the
        // masks they feed — an `fhits & suff` conjunction could hide a
        // divergent bit.
        let g = Grammar::builtin("json").unwrap();
        let corpus = br#"{"alpha": [1, 2.5, true], "beta": "x y"}"#.repeat(30);
        let t = Tokenizer::train(&corpus, 64);
        let tokens = t.participating_tokens(MaskStoreConfig::default().effective_max_token_len());
        let trie = t.token_trie(MaskStoreConfig::default().effective_max_token_len());
        let naive = suffix_match_table(&g, &tokens);
        for (ti, term) in g.terminals.iter().enumerate() {
            if matches!(term.pattern, TermPattern::Declared) {
                continue;
            }
            assert_eq!(trie.suffix_match(&term.dfa), naive[ti], "terminal {ti}");
        }
    }

    #[test]
    fn dead_byte_analysis_prunes_alphabetic_vocab() {
        // calc's INT accepts only digits; a vocabulary trained on pure
        // letters is almost entirely dead bytes for it. The static filter
        // must prune those walks — and change nothing in the output.
        let g = Grammar::builtin("calc").unwrap();
        let corpus = b"the quick brown fox jumps over the lazy dog ".repeat(40);
        let t = Tokenizer::train(&corpus, 80);
        let cfg = MaskStoreConfig::default();
        let trie = MaskStore::build(&g, &t, cfg.clone());
        let reference = MaskStore::build_reference(&g, &t, cfg);
        assert_eq!(trie.to_bytes(), reference.to_bytes());
        assert!(
            trie.stats.pruned_dead_byte > 0,
            "letters must be statically dead for the digit/operator terminals"
        );
        assert!(
            trie.stats.walk_steps < trie.stats.naive_steps / 10,
            "trie+filters must execute far fewer steps than the naive bound \
             ({} vs {})",
            trie.stats.walk_steps,
            trie.stats.naive_steps
        );
        assert_eq!(reference.stats.pruned_dead_byte, 0, "reference never prunes");
        assert_eq!(reference.stats.trie_nodes_visited, 0);
    }

    #[test]
    fn multibyte_utf8_tokens_survive_trie_traversal() {
        // JSON STRING accepts arbitrary non-quote bytes, so multi-byte
        // UTF-8 sequences (é = C3 A9, ✓ = E2 9C 93) must flow through the
        // trie exactly as through the naive walk — high bytes are where a
        // byte/char confusion would bite.
        let g = Grammar::builtin("json").unwrap();
        let mut merges: Vec<(u32, u32)> = vec![(0xC3, 0xA9)]; // é
        merges.push((0xE2, 0x9C));
        merges.push((256 + 1, 0x93)); // ✓
        merges.push((b'"' as u32, 256)); // "é
        let t = Tokenizer::from_merges(&merges);
        let e_acute = 256u32;
        let check = 258u32;
        let quote_e = 259u32;
        assert_eq!(t.token_bytes(e_acute), "é".as_bytes());
        assert_eq!(t.token_bytes(check), "✓".as_bytes());
        let cfg = MaskStoreConfig::default();
        let trie = MaskStore::build(&g, &t, cfg.clone());
        let reference = MaskStore::build_reference(&g, &t, cfg);
        assert_eq!(trie.to_bytes(), reference.to_bytes());
        let string = g.term_id("STRING").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        let inside = dfa.walk(dfa.start(), b"\"a");
        assert!(trie.m0_contains(string, inside, e_acute as usize));
        assert!(trie.m0_contains(string, inside, check as usize));
        assert!(trie.m0_contains(string, dfa.start(), quote_e as usize));
    }

    #[test]
    fn walk_step_counters_populated_and_consistent() {
        let (_, t, s) = store_for("json", 40);
        assert!(s.stats.naive_steps > 0);
        assert!(s.stats.walk_steps > 0);
        assert!(s.stats.trie_nodes_visited > 0);
        assert!(
            s.stats.walk_steps < s.stats.naive_steps,
            "prefix sharing must beat the brute-force bound"
        );
        // The reference build executes real walks too (early-terminating),
        // but visits no trie nodes.
        let g = Grammar::builtin("json").unwrap();
        let r = MaskStore::build_reference(&g, &t, MaskStoreConfig::default());
        assert!(r.stats.walk_steps > 0);
        assert_eq!(r.stats.naive_steps, s.stats.naive_steps);
        assert_eq!(r.stats.trie_nodes_visited, 0);
        // Counters are build-time only: they do not survive a round-trip.
        let loaded = MaskStore::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(loaded.stats.walk_steps, 0);
        assert_eq!(loaded.stats.naive_steps, 0);
    }

    #[test]
    fn reference_build_64_byte_token_parity() {
        // The 64-byte regression token (see suffix_split_survives_64_byte
        // _token) must survive the *trie* path identically: its only
        // F-hit is the final split position, deep in a shared-prefix
        // chain.
        let g = Grammar::builtin("json").unwrap();
        let mut merges: Vec<(u32, u32)> = vec![(b'"' as u32, b'a' as u32)];
        let mut last = 256u32;
        for _ in 0..60 {
            merges.push((last, b'a' as u32));
            last += 1;
        }
        merges.push((last, b'"' as u32));
        last += 1;
        merges.push((last, b'x' as u32));
        last += 1;
        let token = last;
        let tok = Tokenizer::from_merges(&merges);
        assert_eq!(tok.token_bytes(token).len(), 64);
        let trie = MaskStore::build(&g, &tok, MaskStoreConfig::default());
        let reference = MaskStore::build_reference(&g, &tok, MaskStoreConfig::default());
        assert_eq!(trie.to_bytes(), reference.to_bytes());
        let string = g.term_id("STRING").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        assert!(trie.m0_contains(string, dfa.start(), token as usize));
    }
}
