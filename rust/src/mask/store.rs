//! Offline construction of the DFA mask store M₀ / M₁ (Definition 12).
//!
//! Construction (per §4.6 the one-time cost is O(|Q_Ω|·|V|·|Γ|^α)):
//!
//! 1. For every terminal τ and token t, walk t from τ's start state once,
//!    recording `suffmatch(τ, t, i)` = dmatch(t[i..], q₀^τ, {}) for every
//!    suffix start i — the "jump into the next terminal" primitive of
//!    Definition 10 condition 3.
//! 2. For every DFA state q and token t, walk t from q recording
//!    (a) whole-walk liveness (condition 1) and (b) the prefix positions
//!    where the walk sits in a final state (the split points of
//!    conditions 2/3).
//! 3. M₀ and M₁ bits then assemble from these tables without re-walking.
//!
//! Identical masks are interned into a shared pool; tables store pool
//! indices. `MaskStoreStats` reports build time and memory for Table 5.

use crate::grammar::{Grammar, TermId, TermPattern};
use crate::regex::DEAD;
use crate::tokenizer::Tokenizer;
use crate::util::bitset::BitSet;
use std::collections::HashMap;

/// Build options.
#[derive(Debug, Clone)]
pub struct MaskStoreConfig {
    /// Build M₁ (α = 1) in addition to M₀. Without it only 1-length
    /// sequences get precise masks (2-length fall back to M₀ semantics).
    pub with_m1: bool,
    /// Cap on token length considered for prefix-split positions (tokens
    /// longer than this still get condition-1 treatment).
    pub max_token_len: usize,
}

impl Default for MaskStoreConfig {
    fn default() -> Self {
        MaskStoreConfig { with_m1: true, max_token_len: 64 }
    }
}

/// Creation-time/memory statistics (Table 5).
#[derive(Debug, Clone)]
pub struct MaskStoreStats {
    pub build_secs: f64,
    pub vocab_size: usize,
    pub num_dfa_states: usize,
    pub num_terminals: usize,
    pub unique_masks: usize,
    pub m0_entries: usize,
    pub m1_entries: usize,
    /// Bytes held by the interned mask pool + index tables.
    pub mem_bytes: usize,
    /// Bytes the tables would occupy without interning (paper's layout).
    pub raw_bytes: usize,
}

/// The precomputed DFA mask store.
pub struct MaskStore {
    vocab_size: usize,
    eos_id: u32,
    /// Global state index offsets per terminal: state q of terminal τ is
    /// `offsets[τ] + q`.
    offsets: Vec<u32>,
    num_states: usize,
    /// Interned mask pool.
    pool: Vec<BitSet>,
    /// M₀: pool index per global state (u32::MAX = empty mask).
    m0: Vec<u32>,
    /// M₁: pool index per (global state, next terminal); empty when !with_m1.
    m1: Vec<u32>,
    nterms: usize,
    pub stats: MaskStoreStats,
}

const NONE: u32 = u32::MAX;

impl MaskStore {
    /// EOS token id (set on masks only via `eos_ok`).
    pub fn eos_id(&self) -> u32 {
        self.eos_id
    }

    #[inline]
    fn gidx(&self, term: TermId, q: u32) -> usize {
        (self.offsets[term as usize] + q) as usize
    }

    /// Union `M₀(q_τ)` into `out`.
    #[inline]
    pub fn union_m0(&self, term: TermId, q: u32, out: &mut BitSet) {
        let idx = self.m0[self.gidx(term, q)];
        if idx != NONE {
            out.union_with(&self.pool[idx as usize]);
        }
    }

    /// Union `M₁(q_τ, τ_next)` into `out` (falls back to M₀ when M₁ was
    /// not built — a sound over-approximation).
    #[inline]
    pub fn union_m1(&self, term: TermId, q: u32, next: TermId, out: &mut BitSet) {
        if self.m1.is_empty() {
            return self.union_m0(term, q, out);
        }
        let idx = self.m1[self.gidx(term, q) * self.nterms + next as usize];
        if idx != NONE {
            out.union_with(&self.pool[idx as usize]);
        }
    }

    /// Membership test for one token (used by opportunistic masking).
    pub fn m1_contains(&self, term: TermId, q: u32, next: TermId, token: usize) -> bool {
        if self.m1.is_empty() {
            let idx = self.m0[self.gidx(term, q)];
            return idx != NONE && self.pool[idx as usize].get(token);
        }
        let idx = self.m1[self.gidx(term, q) * self.nterms + next as usize];
        idx != NONE && self.pool[idx as usize].get(token)
    }

    pub fn m0_contains(&self, term: TermId, q: u32, token: usize) -> bool {
        let idx = self.m0[self.gidx(term, q)];
        idx != NONE && self.pool[idx as usize].get(token)
    }

    /// Build the store for a grammar × tokenizer pair.
    pub fn build(g: &Grammar, tok: &Tokenizer, cfg: MaskStoreConfig) -> MaskStore {
        let t0 = std::time::Instant::now();
        let nterms = g.terminals.len();
        let vocab_size = tok.vocab_size();

        // Global state numbering.
        let mut offsets = Vec::with_capacity(nterms);
        let mut num_states = 0u32;
        for t in &g.terminals {
            offsets.push(num_states);
            num_states += t.dfa.num_states() as u32;
        }

        // Tokens that participate (non-special, non-empty, not too long).
        let tokens: Vec<(u32, &[u8])> = (0..vocab_size as u32)
            .filter(|&id| !tok.is_special(id))
            .map(|id| (id, tok.token_bytes(id)))
            .filter(|(_, b)| !b.is_empty() && b.len() <= cfg.max_token_len)
            .collect();

        // ---- pass 1: suffmatch(τ, t, i) -------------------------------
        // suff[τ][k] = bitmask over suffix starts i (bit i set ⇔
        // dmatch(t[i..], q0^τ, {})), for token index k.
        let mut suff: Vec<Vec<u64>> = vec![vec![0u64; tokens.len()]; nterms];
        for (term_idx, term) in g.terminals.iter().enumerate() {
            if matches!(term.pattern, TermPattern::Declared) {
                continue; // declared terminals never match text
            }
            let dfa = &term.dfa;
            let suffv = &mut suff[term_idx];
            for (k, &(_, bytes)) in tokens.iter().enumerate() {
                let n = bytes.len().min(63);
                let mut bits = 0u64;
                // dmatch(t[i..], q0, {}) = live-all-the-way OR some strict
                // prefix of the suffix lands in F.
                for i in 0..=n {
                    let mut q = dfa.start();
                    let mut ok = false;
                    if dfa.is_accept(q) && i < n {
                        ok = true; // ε prefix in F with nonempty leftover
                    }
                    if !ok {
                        let mut live = true;
                        for (j, &b) in bytes.iter().enumerate().skip(i) {
                            q = dfa.step(q, b);
                            if q == DEAD {
                                live = false;
                                break;
                            }
                            if dfa.is_accept(q) && j + 1 < bytes.len() {
                                ok = true; // condition 2 split
                                break;
                            }
                        }
                        if live && q != DEAD && dfa.is_live(q) {
                            ok = true; // condition 1
                        }
                        if i == n && n == bytes.len() {
                            // empty suffix: dmatch(ε) = start live
                            ok = dfa.is_live(dfa.start());
                        }
                    }
                    if ok {
                        bits |= 1 << i;
                    }
                }
                suffv[k] = bits;
            }
        }

        // ---- pass 2: per (state, token) walks; assemble M₀ / M₁ --------
        let mut pool: Vec<BitSet> = Vec::new();
        let mut pool_idx: HashMap<u64, Vec<u32>> = HashMap::new(); // hash → candidates
        let mut intern = |mask: BitSet, pool: &mut Vec<BitSet>| -> u32 {
            if mask.is_empty() {
                return NONE;
            }
            use std::hash::{Hash, Hasher};
            let mut h = std::collections::hash_map::DefaultHasher::new();
            mask.hash(&mut h);
            let key = h.finish();
            let cands = pool_idx.entry(key).or_default();
            for &c in cands.iter() {
                if pool[c as usize] == mask {
                    return c;
                }
            }
            let id = pool.len() as u32;
            pool.push(mask);
            cands.push(id);
            id
        };

        let mut m0 = vec![NONE; num_states as usize];
        let mut m1 = if cfg.with_m1 {
            vec![NONE; num_states as usize * nterms]
        } else {
            Vec::new()
        };

        // Reusable per-token scratch: (live_all, fhits bitmask incl. bit len).
        let mut walk_info: Vec<(bool, u64)> = vec![(false, 0); tokens.len()];

        for (term_idx, term) in g.terminals.iter().enumerate() {
            if matches!(term.pattern, TermPattern::Declared) {
                continue;
            }
            let dfa = &term.dfa;
            for q in 0..dfa.num_states() as u32 {
                if !dfa.is_live(q) {
                    continue; // Algorithm 2 never looks up dead states
                }
                // Walk every token from q.
                for (k, &(_, bytes)) in tokens.iter().enumerate() {
                    let mut cur = q;
                    let mut fhits = 0u64;
                    if dfa.is_accept(cur) {
                        fhits |= 1; // i = 0
                    }
                    let mut live_all = true;
                    for (j, &b) in bytes.iter().enumerate() {
                        cur = dfa.step(cur, b);
                        if cur == DEAD {
                            live_all = false;
                            break;
                        }
                        if dfa.is_accept(cur) && j + 1 <= 63 {
                            fhits |= 1 << (j + 1);
                        }
                    }
                    if live_all && !dfa.is_live(cur) {
                        live_all = false;
                    }
                    walk_info[k] = (live_all, fhits);
                }

                // M₀(q): live_all OR a strict-prefix F hit.
                let mut mask = BitSet::new(vocab_size);
                for (k, &(id, bytes)) in tokens.iter().enumerate() {
                    let (live_all, fhits) = walk_info[k];
                    let strict = fhits & ((1u64 << bytes.len().min(63)) - 1);
                    if live_all || strict != 0 {
                        mask.set(id as usize);
                    }
                }
                let g_idx = (offsets[term_idx] + q) as usize;
                m0[g_idx] = intern(mask, &mut pool);

                // M₁(q, τnext): live_all OR some F-hit position i with
                // suffmatch(τnext, t, i).
                if cfg.with_m1 {
                    for nt in 0..nterms {
                        if matches!(g.terminals[nt].pattern, TermPattern::Declared) {
                            continue;
                        }
                        let mut mask = BitSet::new(vocab_size);
                        let suffv = &suff[nt];
                        for (k, &(id, _)) in tokens.iter().enumerate() {
                            let (live_all, fhits) = walk_info[k];
                            if live_all || (fhits & suffv[k]) != 0 {
                                mask.set(id as usize);
                            }
                        }
                        m1[g_idx * nterms + nt] = intern(mask, &mut pool);
                    }
                }
            }
        }

        let mask_bytes = vocab_size.div_ceil(64) * 8;
        let mem_bytes = pool.len() * mask_bytes + (m0.len() + m1.len()) * 4;
        let raw_bytes = (m0.len() + m1.len()) * mask_bytes;
        let stats = MaskStoreStats {
            build_secs: t0.elapsed().as_secs_f64(),
            vocab_size,
            num_dfa_states: num_states as usize,
            num_terminals: nterms,
            unique_masks: pool.len(),
            m0_entries: m0.len(),
            m1_entries: m1.len(),
            mem_bytes,
            raw_bytes,
        };

        MaskStore {
            vocab_size,
            eos_id: tok.eos_id,
            offsets,
            num_states: num_states as usize,
            pool,
            m0,
            m1,
            nterms,
            stats,
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    pub fn num_states(&self) -> usize {
        self.num_states
    }

    /// Serialise to a compact binary blob (paper §4.3: "we cache and
    /// reuse this table for future inferences"). Format: header of u64
    /// dims, then offsets, m0, m1 index tables, then the interned pool.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let push64 = |out: &mut Vec<u8>, v: u64| out.extend_from_slice(&v.to_le_bytes());
        out.extend_from_slice(b"SYNCMSK1");
        push64(&mut out, self.vocab_size as u64);
        push64(&mut out, self.eos_id as u64);
        push64(&mut out, self.num_states as u64);
        push64(&mut out, self.nterms as u64);
        push64(&mut out, self.offsets.len() as u64);
        push64(&mut out, self.m0.len() as u64);
        push64(&mut out, self.m1.len() as u64);
        push64(&mut out, self.pool.len() as u64);
        for &v in &self.offsets {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.m0 {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &v in &self.m1 {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for mask in &self.pool {
            for &w in mask.words() {
                push64(&mut out, w);
            }
        }
        out
    }

    /// Deserialise a blob written by [`MaskStore::to_bytes`].
    pub fn from_bytes(data: &[u8]) -> Result<MaskStore, String> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8], String> {
            if *pos + n > data.len() {
                return Err("truncated mask store blob".into());
            }
            let s = &data[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 8)?;
        if magic != b"SYNCMSK1" {
            return Err("bad mask store magic".into());
        }
        let read64 = |pos: &mut usize| -> Result<u64, String> {
            Ok(u64::from_le_bytes(take(pos, 8)?.try_into().unwrap()))
        };
        let vocab_size = read64(&mut pos)? as usize;
        let eos_id = read64(&mut pos)? as u32;
        let num_states = read64(&mut pos)? as usize;
        let nterms = read64(&mut pos)? as usize;
        let n_off = read64(&mut pos)? as usize;
        let n_m0 = read64(&mut pos)? as usize;
        let n_m1 = read64(&mut pos)? as usize;
        let n_pool = read64(&mut pos)? as usize;
        let read_u32s = |pos: &mut usize, n: usize| -> Result<Vec<u32>, String> {
            let bytes = take(pos, n * 4)?;
            Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
        };
        let offsets = read_u32s(&mut pos, n_off)?;
        let m0 = read_u32s(&mut pos, n_m0)?;
        let m1 = read_u32s(&mut pos, n_m1)?;
        let words_per = vocab_size.div_ceil(64);
        let mut pool = Vec::with_capacity(n_pool);
        for _ in 0..n_pool {
            let bytes = take(&mut pos, words_per * 8)?;
            let words: Vec<u64> = bytes
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pool.push(BitSet::from_words(words, vocab_size));
        }
        let mask_bytes = words_per * 8;
        let mem_bytes = pool.len() * mask_bytes + (m0.len() + m1.len()) * 4;
        let raw_bytes = (m0.len() + m1.len()) * mask_bytes;
        Ok(MaskStore {
            vocab_size,
            eos_id,
            offsets,
            num_states,
            stats: MaskStoreStats {
                build_secs: 0.0,
                vocab_size,
                num_dfa_states: num_states,
                num_terminals: nterms,
                unique_masks: pool.len(),
                m0_entries: m0.len(),
                m1_entries: m1.len(),
                mem_bytes,
                raw_bytes,
            },
            pool,
            m0,
            m1,
            nterms,
        })
    }

    /// Load from `path` when present, else build and cache there.
    pub fn load_or_build(
        path: &std::path::Path,
        g: &Grammar,
        tok: &Tokenizer,
        cfg: MaskStoreConfig,
    ) -> MaskStore {
        if let Ok(data) = std::fs::read(path) {
            if let Ok(s) = MaskStore::from_bytes(&data) {
                if s.vocab_size == tok.vocab_size() {
                    return s;
                }
            }
        }
        let s = MaskStore::build(g, tok, cfg);
        let _ = std::fs::write(path, s.to_bytes());
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grammar::Grammar;

    fn store_for(name: &str, merges: usize) -> (Grammar, Tokenizer, MaskStore) {
        let g = Grammar::builtin(name).unwrap();
        let corpus: Vec<u8> = match name {
            "json" => br#"{"alpha": [1, 2.5, true], "beta": {"s": "x"}, "g": null}"#
                .repeat(40)
                .to_vec(),
            _ => b"math_sqrt(3) * (2.27) + 14 / math_sin(30)".repeat(40).to_vec(),
        };
        let t = Tokenizer::train(&corpus, merges);
        let s = MaskStore::build(&g, &t, MaskStoreConfig::default());
        (g, t, s)
    }

    #[test]
    fn m0_prefix_acceptance_is_conservative() {
        // From a FINAL state of INT, every token is in M₀ (Definition 8's
        // prefix case) — the paper's deliberate over-approximation.
        let (g, t, s) = store_for("calc", 0);
        let int = g.term_id("INT").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        let qf = dfa.walk(dfa.start(), b"4");
        assert!(dfa.is_accept(qf));
        let mut m = BitSet::new(t.vocab_size());
        s.union_m0(int, qf, &mut m);
        // digits extend; '(' is a prefix-split; both allowed
        assert!(m.get(b'5' as usize));
        assert!(m.get(b'(' as usize));
    }

    #[test]
    fn m0_from_start_requires_match_prefix() {
        let (g, t, s) = store_for("calc", 0);
        let int = g.term_id("INT").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        let mut m = BitSet::new(t.vocab_size());
        s.union_m0(int, dfa.start(), &mut m);
        assert!(m.get(b'7' as usize));
        assert!(!m.get(b'x' as usize));
        assert!(!m.get(b'+' as usize));
    }

    #[test]
    fn m1_condition3_jump() {
        // M₁(q0_INT, RPAR): token "3)" walks INT to F then ")" starts RPAR.
        let (g, t, s) = store_for("calc", 50);
        let int = g.term_id("INT").unwrap();
        let rpar = g.term_id("RPAR").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        // find a multibyte token like "3)" if trained, else test byte ")"
        // via a digit-state.
        let q1 = dfa.walk(dfa.start(), b"3");
        let mut m = BitSet::new(t.vocab_size());
        s.union_m1(int, q1, rpar, &mut m);
        assert!(m.get(b')' as usize), "')' completes INT and matches RPAR");
        assert!(m.get(b'1' as usize), "digit keeps INT live");
        assert!(!m.get(b'x' as usize));
    }

    #[test]
    fn interning_dedups() {
        let (_, _, s) = store_for("json", 30);
        assert!(s.stats.unique_masks < s.stats.m0_entries + s.stats.m1_entries);
        assert!(s.stats.mem_bytes < s.stats.raw_bytes);
    }

    #[test]
    fn contains_agrees_with_union() {
        let (g, t, s) = store_for("json", 30);
        let string = g.term_id("STRING").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        let q = dfa.walk(dfa.start(), b"\"ab");
        let ws = g.term_id("WS").unwrap();
        let mut m = BitSet::new(t.vocab_size());
        s.union_m1(string, q, ws, &mut m);
        for id in 0..t.vocab_size() {
            assert_eq!(m.get(id), s.m1_contains(string, q, ws, id), "token {id}");
        }
    }

    #[test]
    fn m1_brute_force_agreement() {
        // Cross-check the assembled M₁ against a direct recursive dmatch
        // implementation on a byte-level vocabulary.
        let (g, t, s) = store_for("calc", 0);
        fn dmatch(
            g: &Grammar,
            term: TermId,
            q: u32,
            bytes: &[u8],
            lam: &[TermId],
        ) -> bool {
            let dfa = &g.terminals[term as usize].dfa;
            // condition 1
            let mut cur = q;
            let mut alive = true;
            for &b in bytes {
                cur = dfa.step(cur, b);
                if cur == DEAD {
                    alive = false;
                    break;
                }
            }
            if alive && dfa.is_live(cur) {
                return true;
            }
            // splits
            for i in 0..=bytes.len() {
                let w1 = &bytes[..i];
                let mut cur = q;
                let mut dead = false;
                for &b in w1 {
                    cur = dfa.step(cur, b);
                    if cur == DEAD {
                        dead = true;
                        break;
                    }
                }
                if dead || !dfa.is_accept(cur) {
                    continue;
                }
                let w2 = &bytes[i..];
                match lam.split_first() {
                    None => {
                        if !w2.is_empty() {
                            return true; // condition 2
                        }
                    }
                    Some((&nxt, rest)) => {
                        let ndfa = &g.terminals[nxt as usize].dfa;
                        if dmatch(g, nxt, ndfa.start(), w2, rest) {
                            return true; // condition 3
                        }
                    }
                }
            }
            false
        }
        let int = g.term_id("INT").unwrap();
        let plus = g.term_id("PLUS").unwrap();
        let dfa = &g.terminals[int as usize].dfa;
        for probe in [b"1".as_slice(), b"12", b""] {
            let q = dfa.walk(dfa.start(), probe);
            if !dfa.is_live(q) {
                continue;
            }
            for id in 0..256u32 {
                let bytes = t.token_bytes(id).to_vec();
                if bytes.is_empty() {
                    continue;
                }
                let expect = dmatch(&g, int, q, &bytes, &[plus]);
                assert_eq!(
                    s.m1_contains(int, q, plus, id as usize),
                    expect,
                    "token {:?} from r={:?}",
                    bytes,
                    probe
                );
            }
        }
    }

    #[test]
    fn serialisation_roundtrip() {
        let (g, t, s) = store_for("json", 40);
        let blob = s.to_bytes();
        let s2 = MaskStore::from_bytes(&blob).unwrap();
        assert_eq!(s.vocab_size(), s2.vocab_size());
        assert_eq!(s.num_states(), s2.num_states());
        // Every lookup agrees.
        let string = g.term_id("STRING").unwrap();
        let ws = g.term_id("WS").unwrap();
        let dfa = &g.terminals[string as usize].dfa;
        for probe in [b"\"a".as_slice(), b"\"xy", b"\""] {
            let q = dfa.walk(dfa.start(), probe);
            for id in 0..t.vocab_size() {
                assert_eq!(
                    s.m0_contains(string, q, id),
                    s2.m0_contains(string, q, id)
                );
                assert_eq!(
                    s.m1_contains(string, q, ws, id),
                    s2.m1_contains(string, q, ws, id)
                );
            }
        }
    }

    #[test]
    fn from_bytes_rejects_garbage() {
        assert!(MaskStore::from_bytes(b"nope").is_err());
        assert!(MaskStore::from_bytes(b"SYNCMSK1short").is_err());
    }

    #[test]
    fn load_or_build_caches() {
        let (g, t, _) = store_for("calc", 10);
        let dir = std::env::temp_dir().join("syncode_store_test");
        let _ = std::fs::remove_file(&dir);
        let s1 = MaskStore::load_or_build(&dir, &g, &t, MaskStoreConfig::default());
        assert!(dir.exists());
        let s2 = MaskStore::load_or_build(&dir, &g, &t, MaskStoreConfig::default());
        assert_eq!(s1.stats.unique_masks, s2.stats.unique_masks);
        assert_eq!(s2.stats.build_secs, 0.0); // loaded, not rebuilt
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn stats_populated() {
        let (_, _, s) = store_for("calc", 20);
        assert!(s.stats.build_secs >= 0.0);
        assert!(s.stats.num_dfa_states > 10);
        assert!(s.stats.mem_bytes > 0);
    }
}
