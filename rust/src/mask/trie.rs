//! Byte trie over the participating vocabulary — the shared index behind
//! the fast mask-store build.
//!
//! The naive build walks every token from every (terminal, state) item
//! independently: `Σ|Q_Ω| · Σ|t|` DFA steps. The trie exploits the fact
//! that BPE vocabularies are extremely prefix-dense: tokens sharing a
//! prefix share every step over that prefix, and once a walk leaves
//! `live(Q)` **every** token below the current trie node is resolved at
//! once (no suffix of a dead walk can revive it). Two static filters cut
//! further:
//!
//! - **dead-byte pruning** ([`crate::regex::Dfa::dead_classes`]): a byte
//!   whose class is `DEAD` from every live state disqualifies the whole
//!   subtree before any step executes;
//! - **byte-class projection**: sibling edges whose bytes fall in the
//!   same equivalence class for the current terminal share one
//!   `step_class` call.
//!
//! The trie is a pure function of (vocabulary, token-length cap) — one
//! per tokenizer, shared across every grammar compiled against it (see
//! `Tokenizer::token_trie`). Nodes are laid out depth-first with
//! contiguous children; each node records the contiguous range of
//! lexicographically-sorted token indices below it, so a pruned subtree
//! resolves to a slice fill. Results are written into a table indexed by
//! token, which is what makes DFS visit order irrelevant to the
//! bit-identical-output guarantee of the sharded build.

use crate::regex::Dfa;

/// Index into the participating-token list (the builder's `tokens`
/// vector, in token-id order) — *not* a vocabulary id.
type TokIx = u32;

#[derive(Debug)]
struct Node {
    /// Edge byte from the parent (unused sentinel 0 at the root).
    byte: u8,
    /// Number of tokens ending exactly at this node (> 1 only when the
    /// tokenizer maps several ids to the same byte string). They occupy
    /// the first `n_end` slots of the subtree's token range.
    n_end: u32,
    /// Children occupy `nodes[child_lo..child_hi]`, in byte order.
    child_lo: u32,
    child_hi: u32,
    /// Tokens in this subtree occupy `dfs_tokens[tok_lo..tok_hi]`.
    tok_lo: u32,
    tok_hi: u32,
}

/// Prefix trie over the participating tokens of one tokenizer.
pub struct TokenTrie {
    nodes: Vec<Node>,
    /// Token indices sorted lexicographically by byte string (stable by
    /// index), arranged so every subtree is a contiguous range.
    dfs_tokens: Vec<TokIx>,
    /// Vocabulary id per token index, in token-id order — the builder's
    /// canonical token enumeration.
    token_ids: Vec<u32>,
    /// Σ token bytes — the naive per-item walk cost.
    total_token_bytes: u64,
    /// Length cap the token set was filtered with.
    max_token_len: usize,
}

/// Counters for one build's trie walks (merged across shards into
/// `MaskStoreStats`).
#[derive(Debug, Default, Clone, Copy)]
pub struct TrieWalkStats {
    /// `dfa.step`/`step_class` calls actually executed.
    pub steps: u64,
    /// Trie nodes entered (pruned subtrees are not entered).
    pub nodes_visited: u64,
    /// Token walks resolved by static dead-byte pruning, i.e. without
    /// reaching the byte at all.
    pub pruned_dead_byte: u64,
}

impl TrieWalkStats {
    pub fn merge(&mut self, o: &TrieWalkStats) {
        self.steps += o.steps;
        self.nodes_visited += o.nodes_visited;
        self.pruned_dead_byte += o.pruned_dead_byte;
    }
}

/// Reusable per-worker scratch for [`TokenTrie::walk_masks`] (per-depth
/// sibling-transition buffers; taking them out of the walker avoids one
/// allocation per visited node).
#[derive(Default)]
pub struct TrieScratch {
    levels: Vec<Vec<ClassStep>>,
}

/// One resolved sibling transition: every later sibling edge whose byte
/// falls in the same class reuses `next` instead of stepping again.
#[derive(Clone, Copy)]
struct ClassStep {
    class: u16,
    next: u32,
}

impl TokenTrie {
    /// Build the trie over `tokens` — `(vocab id, bytes)` pairs in token-id
    /// order, already filtered to the participating set (non-special,
    /// non-empty, `len <= max_token_len`). `max_token_len` is recorded so
    /// cached tries can be validated against a build's config.
    pub fn build(tokens: &[(u32, &[u8])], max_token_len: usize) -> TokenTrie {
        debug_assert!(tokens.iter().all(|(_, b)| !b.is_empty() && b.len() <= max_token_len));
        let token_ids: Vec<u32> = tokens.iter().map(|&(id, _)| id).collect();
        let total_token_bytes: u64 = tokens.iter().map(|&(_, b)| b.len() as u64).sum();

        let mut dfs_tokens: Vec<TokIx> = (0..tokens.len() as u32).collect();
        dfs_tokens.sort_by(|&a, &b| {
            tokens[a as usize].1.cmp(tokens[b as usize].1).then(a.cmp(&b))
        });

        let mut trie = TokenTrie {
            nodes: vec![Node {
                byte: 0,
                n_end: 0,
                child_lo: 0,
                child_hi: 0,
                tok_lo: 0,
                tok_hi: tokens.len() as u32,
            }],
            dfs_tokens,
            token_ids,
            total_token_bytes,
            max_token_len,
        };
        trie.split(0, 0, tokens);
        trie
    }

    /// Recursively partition `nodes[node]`'s token range (sorted, all
    /// sharing the first `depth` bytes) into end-tokens and per-byte
    /// children. Recursion depth is bounded by `max_token_len` (≤ 127).
    fn split(&mut self, node: usize, depth: usize, tokens: &[(u32, &[u8])]) {
        let (lo, hi) = {
            let n = &self.nodes[node];
            (n.tok_lo as usize, n.tok_hi as usize)
        };
        // Tokens ending here sort first (a prefix orders before its
        // extensions).
        let mut i = lo;
        while i < hi && tokens[self.dfs_tokens[i] as usize].1.len() == depth {
            i += 1;
        }
        self.nodes[node].n_end = (i - lo) as u32;
        // Group the rest by their byte at `depth`; groups are contiguous
        // and in byte order because the range is sorted.
        let child_lo = self.nodes.len();
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        while i < hi {
            let b = tokens[self.dfs_tokens[i] as usize].1[depth];
            let start = i;
            while i < hi && tokens[self.dfs_tokens[i] as usize].1[depth] == b {
                i += 1;
            }
            self.nodes.push(Node {
                byte: b,
                n_end: 0,
                child_lo: 0,
                child_hi: 0,
                tok_lo: start as u32,
                tok_hi: i as u32,
            });
            ranges.push((self.nodes.len() - 1, depth + 1));
        }
        self.nodes[node].child_lo = child_lo as u32;
        self.nodes[node].child_hi = self.nodes.len() as u32;
        for (child, d) in ranges {
            self.split(child, d, tokens);
        }
    }

    /// Vocabulary ids of the participating tokens, in token-id order.
    pub fn token_ids(&self) -> &[u32] {
        &self.token_ids
    }

    pub fn num_tokens(&self) -> usize {
        self.token_ids.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Σ token bytes: one item's walk cost in the naive build.
    pub fn total_token_bytes(&self) -> u64 {
        self.total_token_bytes
    }

    /// The token-length cap this trie was filtered with.
    pub fn max_token_len(&self) -> usize {
        self.max_token_len
    }

    /// One (terminal, state) item of the mask-store build: fill
    /// `walk_info[k] = (live_all, fhits)` for every token index `k`,
    /// bit-identically to the naive per-token walk. `q` must be live;
    /// `dead` is the terminal's [`Dfa::dead_classes`] table.
    ///
    /// `fhits` bit `i` means the walk's `i`-byte prefix sits in a final
    /// state; `live_all` means the whole walk stayed alive *and* landed
    /// live. A subtree is pruned as soon as the walk leaves `live(Q)`:
    /// accept states are always live, so from a non-live state no further
    /// F-hits can accrue and every deeper token resolves to
    /// `(false, fhits-so-far)` — exactly what the naive walk computes.
    pub fn walk_masks(
        &self,
        dfa: &Dfa,
        q: u32,
        dead: &[bool],
        walk_info: &mut [(bool, u128)],
        scratch: &mut TrieScratch,
        stats: &mut TrieWalkStats,
    ) {
        debug_assert!(dfa.is_live(q));
        debug_assert_eq!(walk_info.len(), self.num_tokens());
        debug_assert_eq!(dead.len(), dfa.num_classes());
        if scratch.levels.len() < self.max_token_len + 1 {
            scratch.levels.resize_with(self.max_token_len + 1, Vec::new);
        }
        let fhits = if dfa.is_accept(q) { 1u128 } else { 0 };
        let mut w = MaskWalk { trie: self, dfa, dead, walk_info, stats };
        w.rec(0, q, fhits, 0, &mut scratch.levels);
    }

    /// Fill every token in `node`'s subtree with `v`.
    fn fill(&self, node: &Node, v: (bool, u128), walk_info: &mut [(bool, u128)]) {
        for &k in &self.dfs_tokens[node.tok_lo as usize..node.tok_hi as usize] {
            walk_info[k as usize] = v;
        }
    }

    /// Pass-1 counterpart: `suffmatch(τ, t, i)` for every token in one DFS
    /// over the trie, semantically identical to the naive per-suffix walk.
    ///
    /// The walk threads a set of *active* suffix starts down the trie —
    /// `(i, state)` pairs for every start whose walk from `q₀` is still in
    /// a live state with no F-hit yet — plus a `decided` bitmask of starts
    /// already proven (an F state reached strictly before the current
    /// depth satisfies condition 2 for every deeper token end). A token
    /// ending at depth `d` reads `decided | every-active-bit`: an active
    /// entry *is* condition 1 (its walk covered the whole suffix and sits
    /// live). A fresh start `(d, q₀)` joins at every depth — that entry
    /// doubles as the empty-suffix case `dmatch(ε) = live(q₀)`.
    pub fn suffix_match(&self, dfa: &Dfa) -> Vec<u128> {
        let mut out = vec![0u128; self.num_tokens()];
        let start = dfa.start();
        let start_live = dfa.is_live(start);
        let mut levels: Vec<Vec<(u8, u32)>> =
            (0..self.max_token_len + 1).map(|_| Vec::new()).collect();
        if start_live {
            levels[0].push((0, start));
        }
        let mut w = SuffWalk { trie: self, dfa, start, start_live, out: &mut out };
        w.rec(0, 0, 0, &mut levels);
        out
    }
}

/// Borrow bundle for one [`TokenTrie::walk_masks`] DFS.
struct MaskWalk<'a> {
    trie: &'a TokenTrie,
    dfa: &'a Dfa,
    dead: &'a [bool],
    walk_info: &'a mut [(bool, u128)],
    stats: &'a mut TrieWalkStats,
}

impl MaskWalk<'_> {
    /// Visit `node` with the walk in live `state` at `depth`, `fhits`
    /// holding the F-hit bits of the path so far (bit `depth` included).
    fn rec(
        &mut self,
        node: u32,
        state: u32,
        fhits: u128,
        depth: usize,
        levels: &mut [Vec<ClassStep>],
    ) {
        self.stats.nodes_visited += 1;
        let n = &self.trie.nodes[node as usize];
        // Tokens ending here: the walk covered them fully and sits live.
        for &k in &self.trie.dfs_tokens
            [n.tok_lo as usize..n.tok_lo as usize + n.n_end as usize]
        {
            self.walk_info[k as usize] = (true, fhits);
        }
        if n.child_lo == n.child_hi {
            return;
        }
        let (buf_slot, deeper) = levels.split_first_mut().expect("levels sized to max depth");
        let mut buf = std::mem::take(buf_slot);
        buf.clear();
        for ci in n.child_lo..n.child_hi {
            let c = &self.trie.nodes[ci as usize];
            let class = self.dfa.byte_class(c.byte);
            if self.dead[class as usize] {
                // Static filter: this byte kills every live state, so the
                // whole subtree dies here without a step.
                self.stats.pruned_dead_byte += (c.tok_hi - c.tok_lo) as u64;
                self.trie.fill(c, (false, fhits), self.walk_info);
                continue;
            }
            // Byte-class projection: reuse an earlier sibling's step.
            let next = match buf.iter().find(|e| e.class == class) {
                Some(e) => e.next,
                None => {
                    self.stats.steps += 1;
                    let nx = self.dfa.step_class(state, class);
                    buf.push(ClassStep { class, next: nx });
                    nx
                }
            };
            if !self.dfa.is_live(next) {
                // DEAD or merely non-live: no deeper F-hits are possible
                // and every deeper walk ends non-live → resolve the
                // subtree (matches the naive walk bit-for-bit).
                self.trie.fill(c, (false, fhits), self.walk_info);
                continue;
            }
            let child_fhits = if self.dfa.is_accept(next) {
                fhits | (1u128 << (depth + 1))
            } else {
                fhits
            };
            self.rec(ci, next, child_fhits, depth + 1, deeper);
        }
        *buf_slot = buf;
    }
}

/// Borrow bundle for one [`TokenTrie::suffix_match`] DFS.
struct SuffWalk<'a> {
    trie: &'a TokenTrie,
    dfa: &'a Dfa,
    start: u32,
    start_live: bool,
    out: &'a mut [u128],
}

impl SuffWalk<'_> {
    /// Visit `node` at `depth`; `levels[0]` holds the active suffix
    /// starts for this node, `decided` the starts already proven via a
    /// strict-prefix F-hit (condition 2).
    fn rec(
        &mut self,
        node: u32,
        depth: usize,
        decided: u128,
        levels: &mut [Vec<(u8, u32)>],
    ) {
        let n = &self.trie.nodes[node as usize];
        if n.n_end > 0 {
            // Active ⇒ the walk covered the whole suffix and is live:
            // condition 1. Decided ⇒ condition 2 hit strictly inside.
            let mut bits = decided;
            for &(i, _) in levels[0].iter() {
                bits |= 1u128 << i;
            }
            for &k in &self.trie.dfs_tokens
                [n.tok_lo as usize..n.tok_lo as usize + n.n_end as usize]
            {
                self.out[k as usize] = bits;
            }
        }
        if n.child_lo == n.child_hi {
            return;
        }
        let (active_slot, deeper) = levels.split_first_mut().expect("levels sized to max depth");
        let active = std::mem::take(active_slot);
        for ci in n.child_lo..n.child_hi {
            let b = self.trie.nodes[ci as usize].byte;
            let mut decided_c = decided;
            let next_buf = &mut deeper[0];
            next_buf.clear();
            for &(i, st) in &active {
                if self.dfa.is_accept(st) {
                    // F at depth `depth`, strictly before any deeper token
                    // end — permanently decided for this subtree.
                    decided_c |= 1u128 << i;
                    continue;
                }
                let nx = self.dfa.step(st, b);
                if self.dfa.is_live(nx) {
                    next_buf.push((i, nx));
                }
                // Non-live: no future F-hit and no live landing — the
                // start is resolved false for every deeper token.
            }
            if self.start_live {
                next_buf.push(((depth + 1) as u8, self.start));
            }
            self.rec(ci, depth + 1, decided_c, deeper);
        }
        *active_slot = active;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trie_of(strs: &[&[u8]]) -> TokenTrie {
        let tokens: Vec<(u32, &[u8])> =
            strs.iter().enumerate().map(|(i, &b)| (i as u32 + 7, b)).collect();
        TokenTrie::build(&tokens, 127)
    }

    #[test]
    fn structure_prefix_sharing() {
        let t = trie_of(&[b"ab", b"ac", b"a", b"b"]);
        assert_eq!(t.num_tokens(), 4);
        // root + 'a' + 'b'(top) + 'ab' + 'ac' = 5 nodes
        assert_eq!(t.num_nodes(), 5);
        assert_eq!(t.total_token_bytes(), 6);
        assert_eq!(t.token_ids(), &[7, 8, 9, 10]);
    }

    #[test]
    fn duplicate_byte_strings_each_get_a_slot() {
        let t = trie_of(&[b"xy", b"xy", b"x"]);
        assert_eq!(t.num_tokens(), 3);
        // root, 'x', 'xy' — both "xy" tokens end at the same node.
        assert_eq!(t.num_nodes(), 3);
        let n = t
            .nodes
            .iter()
            .find(|n| n.byte == b'y')
            .expect("xy node");
        assert_eq!(n.n_end, 2);
    }

    #[test]
    fn subtree_ranges_are_contiguous_and_complete() {
        let t = trie_of(&[b"cat", b"car", b"cart", b"dog", b"do"]);
        let root = &t.nodes[0];
        assert_eq!((root.tok_lo, root.tok_hi), (0, 5));
        for n in &t.nodes {
            assert!(n.tok_lo <= n.tok_hi);
            assert!(n.tok_lo as usize + n.n_end as usize <= n.tok_hi as usize);
            // children partition the non-ending remainder
            let mut covered = n.tok_lo + n.n_end;
            for ci in n.child_lo..n.child_hi {
                let c = &t.nodes[ci as usize];
                assert_eq!(c.tok_lo, covered);
                covered = c.tok_hi;
            }
            assert_eq!(covered, n.tok_hi);
        }
        // Every token index appears exactly once.
        let mut seen = t.dfs_tokens.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..5).collect::<Vec<_>>());
    }
}
