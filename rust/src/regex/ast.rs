//! Regex syntax tree and parser (Lark/PCRE-ish subset).
//!
//! Supported: literals, `.`, escapes (`\d \w \s \n \t \r \f \. \\ …`),
//! character classes `[...]` with ranges and negation, grouping `(...)`,
//! alternation `|`, repetition `* + ? {m} {m,} {m,n}` and their non-greedy
//! variants (`*?` etc. — same *language*, so treated identically; see
//! module docs), and inline `(?i:...)`-free case folding via the terminal's
//! `/…/i` flag which is applied to the whole AST.
//!
//! Not supported (rejected with an error): anchors `^ $`, backreferences,
//! lookaround. The grammars in `grammars/` avoid them.

/// 256-bit set of bytes.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct ByteSet(pub [u64; 4]);

impl ByteSet {
    pub const EMPTY: ByteSet = ByteSet([0; 4]);

    pub fn single(b: u8) -> ByteSet {
        let mut s = ByteSet::EMPTY;
        s.insert(b);
        s
    }

    pub fn range(lo: u8, hi: u8) -> ByteSet {
        let mut s = ByteSet::EMPTY;
        for b in lo..=hi {
            s.insert(b);
        }
        s
    }

    pub fn insert(&mut self, b: u8) {
        self.0[(b >> 6) as usize] |= 1u64 << (b & 63);
    }

    pub fn contains(&self, b: u8) -> bool {
        (self.0[(b >> 6) as usize] >> (b & 63)) & 1 == 1
    }

    pub fn union(mut self, other: ByteSet) -> ByteSet {
        for i in 0..4 {
            self.0[i] |= other.0[i];
        }
        self
    }

    pub fn negate(mut self) -> ByteSet {
        for i in 0..4 {
            self.0[i] = !self.0[i];
        }
        self
    }

    pub fn is_empty(&self) -> bool {
        self.0.iter().all(|&w| w == 0)
    }

    pub fn iter(&self) -> impl Iterator<Item = u8> + '_ {
        (0u16..=255).map(|b| b as u8).filter(move |&b| self.contains(b))
    }
}

impl std::fmt::Debug for ByteSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ByteSet{{{} bytes}}", self.iter().count())
    }
}

/// Regex abstract syntax tree.
#[derive(Clone, Debug, PartialEq)]
pub enum RegexAst {
    /// The empty string ε.
    Empty,
    /// A byte class (single chars are 1-element classes).
    Class(ByteSet),
    /// A byte-literal sequence (fast path for keywords).
    Literal(Vec<u8>),
    Concat(Vec<RegexAst>),
    Alt(Vec<RegexAst>),
    Star(Box<RegexAst>),
    Plus(Box<RegexAst>),
    Opt(Box<RegexAst>),
    /// `{min, max}`; `max == usize::MAX` means unbounded.
    Repeat(Box<RegexAst>, usize, usize),
}

impl RegexAst {
    /// Saturating estimate of the Thompson NFA size this AST expands to.
    ///
    /// Mirrors the construction in `nfa.rs` (including its repetition
    /// expansion cap of 64), so callers can reject a pathological pattern
    /// — e.g. nested counted repeats like `(a{64}){64}{64}` whose state
    /// count multiplies per nesting level — *before* allocating the NFA.
    pub fn nfa_size_estimate(&self) -> usize {
        const REPEAT_CAP: usize = 64; // keep in sync with nfa.rs
        match self {
            RegexAst::Empty => 2,
            RegexAst::Class(_) => 2,
            RegexAst::Literal(bytes) => bytes.len().saturating_add(1),
            RegexAst::Concat(parts) | RegexAst::Alt(parts) => parts
                .iter()
                .fold(2usize, |acc, p| acc.saturating_add(p.nfa_size_estimate())),
            RegexAst::Star(inner) | RegexAst::Opt(inner) => {
                inner.nfa_size_estimate().saturating_add(2)
            }
            RegexAst::Plus(inner) => inner.nfa_size_estimate().saturating_add(1),
            RegexAst::Repeat(inner, lo, hi) => {
                let (lo, hi) = (*lo, *hi);
                let copies = lo.min(REPEAT_CAP)
                    + if hi == usize::MAX {
                        1
                    } else {
                        hi.min(REPEAT_CAP).saturating_sub(lo)
                    };
                copies
                    .saturating_mul(inner.nfa_size_estimate().saturating_add(2))
                    .saturating_add(2)
            }
        }
    }

    /// Fold ASCII case: every letter class/literal accepts both cases.
    pub fn case_insensitive(self) -> RegexAst {
        match self {
            RegexAst::Class(mut s) => {
                let orig = s;
                for b in orig.iter() {
                    if b.is_ascii_lowercase() {
                        s.insert(b.to_ascii_uppercase());
                    } else if b.is_ascii_uppercase() {
                        s.insert(b.to_ascii_lowercase());
                    }
                }
                RegexAst::Class(s)
            }
            RegexAst::Literal(bytes) => RegexAst::Concat(
                bytes
                    .into_iter()
                    .map(|b| {
                        if b.is_ascii_alphabetic() {
                            let mut s = ByteSet::single(b.to_ascii_lowercase());
                            s.insert(b.to_ascii_uppercase());
                            RegexAst::Class(s)
                        } else {
                            RegexAst::Class(ByteSet::single(b))
                        }
                    })
                    .collect(),
            ),
            RegexAst::Concat(xs) => {
                RegexAst::Concat(xs.into_iter().map(|x| x.case_insensitive()).collect())
            }
            RegexAst::Alt(xs) => {
                RegexAst::Alt(xs.into_iter().map(|x| x.case_insensitive()).collect())
            }
            RegexAst::Star(x) => RegexAst::Star(Box::new(x.case_insensitive())),
            RegexAst::Plus(x) => RegexAst::Plus(Box::new(x.case_insensitive())),
            RegexAst::Opt(x) => RegexAst::Opt(Box::new(x.case_insensitive())),
            RegexAst::Repeat(x, lo, hi) => {
                RegexAst::Repeat(Box::new(x.case_insensitive()), lo, hi)
            }
            other => other,
        }
    }
}

/// Regex parse error.
#[derive(Debug, Clone, PartialEq)]
pub struct RegexError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for RegexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "regex error at {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for RegexError {}

/// Parse a regex pattern into an AST.
pub fn parse_regex(pattern: &str) -> Result<RegexAst, RegexError> {
    let mut p = P { b: pattern.as_bytes(), pos: 0, depth: 0 };
    let ast = p.alt()?;
    if p.pos != p.b.len() {
        return Err(p.err("unexpected trailing content"));
    }
    Ok(ast)
}

struct P<'a> {
    b: &'a [u8],
    pos: usize,
    /// Current group-nesting depth. Capped so a pathological `((((…`
    /// pattern is a parse error, not a recursion stack overflow.
    depth: usize,
}

/// Maximum group-nesting depth for untrusted patterns (recursive descent).
const MAX_REGEX_DEPTH: usize = 512;

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> RegexError {
        RegexError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn alt(&mut self) -> Result<RegexAst, RegexError> {
        let mut branches = vec![self.concat()?];
        while self.peek() == Some(b'|') {
            self.pos += 1;
            branches.push(self.concat()?);
        }
        Ok(if branches.len() == 1 { branches.pop().unwrap() } else { RegexAst::Alt(branches) })
    }

    fn concat(&mut self) -> Result<RegexAst, RegexError> {
        let mut parts = Vec::new();
        while let Some(c) = self.peek() {
            if c == b'|' || c == b')' {
                break;
            }
            parts.push(self.repeat()?);
        }
        Ok(match parts.len() {
            0 => RegexAst::Empty,
            1 => parts.pop().unwrap(),
            _ => RegexAst::Concat(parts),
        })
    }

    fn repeat(&mut self) -> Result<RegexAst, RegexError> {
        let atom = self.atom()?;
        let mut node = atom;
        loop {
            match self.peek() {
                Some(b'*') => {
                    self.pos += 1;
                    self.skip_nongreedy();
                    node = RegexAst::Star(Box::new(node));
                }
                Some(b'+') => {
                    self.pos += 1;
                    self.skip_nongreedy();
                    node = RegexAst::Plus(Box::new(node));
                }
                Some(b'?') => {
                    self.pos += 1;
                    self.skip_nongreedy();
                    node = RegexAst::Opt(Box::new(node));
                }
                Some(b'{') => {
                    // Could be a counted repetition or a literal '{'.
                    if let Some((lo, hi, consumed)) = self.try_counted() {
                        self.pos += consumed;
                        self.skip_nongreedy();
                        node = RegexAst::Repeat(Box::new(node), lo, hi);
                    } else {
                        break;
                    }
                }
                _ => break,
            }
        }
        Ok(node)
    }

    /// `{m}`, `{m,}`, `{m,n}` starting at self.pos (which points at '{').
    /// Returns (lo, hi, bytes_consumed) or None if not a counted form.
    fn try_counted(&self) -> Option<(usize, usize, usize)> {
        let rest = &self.b[self.pos..];
        let close = rest.iter().position(|&c| c == b'}')?;
        let inner = std::str::from_utf8(&rest[1..close]).ok()?;
        if inner.is_empty() {
            return None;
        }
        let (lo_s, hi_s) = match inner.split_once(',') {
            Some((a, b)) => (a, Some(b)),
            None => (inner, None),
        };
        let lo: usize = lo_s.parse().ok()?;
        let hi = match hi_s {
            None => lo,
            Some("") => usize::MAX,
            Some(h) => h.parse().ok()?,
        };
        Some((lo, hi, close + 1))
    }

    fn skip_nongreedy(&mut self) {
        if self.peek() == Some(b'?') {
            self.pos += 1; // same language; greediness is a matcher concern
        }
    }

    fn atom(&mut self) -> Result<RegexAst, RegexError> {
        match self.peek() {
            Some(b'(') => {
                self.pos += 1;
                self.depth += 1;
                if self.depth > MAX_REGEX_DEPTH {
                    return Err(self.err("group nesting too deep"));
                }
                // (?: ...) non-capturing and (?s:...)/(?i...) inline flags:
                // strip the prefix; `s` only affects '.', handled globally.
                if self.peek() == Some(b'?') {
                    self.pos += 1;
                    while matches!(self.peek(), Some(b's' | b'i' | b'm' | b'x')) {
                        self.pos += 1;
                    }
                    if self.peek() == Some(b':') {
                        self.pos += 1;
                    }
                }
                let inner = self.alt()?;
                self.depth -= 1;
                if self.bump() != Some(b')') {
                    return Err(self.err("unclosed group"));
                }
                Ok(inner)
            }
            Some(b'[') => self.class(),
            Some(b'.') => {
                self.pos += 1;
                // '.' matches any byte except \n (multiline grammars rely
                // on this to keep comments/strings on one line).
                let mut s = ByteSet::EMPTY.negate();
                s.0[0] &= !(1u64 << b'\n');
                Ok(RegexAst::Class(s))
            }
            Some(b'\\') => {
                self.pos += 1;
                let c = self.bump().ok_or_else(|| self.err("dangling escape"))?;
                Ok(RegexAst::Class(escape_class(c).ok_or_else(|| {
                    self.err(&format!("unsupported escape \\{}", c as char))
                })?))
            }
            Some(b'^') | Some(b'$') => Err(self.err("anchors are not supported")),
            Some(b'*') | Some(b'+') | Some(b'?') => Err(self.err("dangling quantifier")),
            Some(c) => {
                self.pos += 1;
                Ok(RegexAst::Class(ByteSet::single(c)))
            }
            None => Err(self.err("unexpected end of pattern")),
        }
    }

    fn class(&mut self) -> Result<RegexAst, RegexError> {
        assert_eq!(self.bump(), Some(b'['));
        let negated = if self.peek() == Some(b'^') {
            self.pos += 1;
            true
        } else {
            false
        };
        let mut set = ByteSet::EMPTY;
        let mut first = true;
        loop {
            let c = self.peek().ok_or_else(|| self.err("unclosed class"))?;
            if c == b']' && !first {
                self.pos += 1;
                break;
            }
            first = false;
            let lo = self.class_char()?;
            // Range?
            if self.peek() == Some(b'-')
                && self.b.get(self.pos + 1).map(|&c| c != b']').unwrap_or(false)
            {
                self.pos += 1;
                let hi_set = self.class_char()?;
                // Ranges only make sense between single chars.
                let (lo_b, hi_b) = match (single_byte(&lo), single_byte(&hi_set)) {
                    (Some(a), Some(b)) if a <= b => (a, b),
                    _ => return Err(self.err("bad range in class")),
                };
                set = set.union(ByteSet::range(lo_b, hi_b));
            } else {
                set = set.union(lo);
            }
        }
        let set = if negated {
            set.negate()
        } else {
            set
        };
        if set.is_empty() {
            return Err(self.err("empty character class"));
        }
        Ok(RegexAst::Class(set))
    }

    /// One class member: either a literal byte or an escape class.
    fn class_char(&mut self) -> Result<ByteSet, RegexError> {
        let c = self.bump().ok_or_else(|| self.err("unclosed class"))?;
        if c == b'\\' {
            let e = self.bump().ok_or_else(|| self.err("dangling escape in class"))?;
            escape_class(e).ok_or_else(|| self.err(&format!("unsupported escape \\{}", e as char)))
        } else {
            Ok(ByteSet::single(c))
        }
    }
}

fn single_byte(s: &ByteSet) -> Option<u8> {
    let mut it = s.iter();
    let b = it.next()?;
    if it.next().is_none() {
        Some(b)
    } else {
        None
    }
}

fn escape_class(c: u8) -> Option<ByteSet> {
    Some(match c {
        b'n' => ByteSet::single(b'\n'),
        b'r' => ByteSet::single(b'\r'),
        b't' => ByteSet::single(b'\t'),
        b'f' => ByteSet::single(0x0C),
        b'v' => ByteSet::single(0x0B),
        b'0' => ByteSet::single(0),
        b'd' => ByteSet::range(b'0', b'9'),
        b'D' => ByteSet::range(b'0', b'9').negate(),
        b'w' => ByteSet::range(b'a', b'z')
            .union(ByteSet::range(b'A', b'Z'))
            .union(ByteSet::range(b'0', b'9'))
            .union(ByteSet::single(b'_')),
        b'W' => ByteSet::range(b'a', b'z')
            .union(ByteSet::range(b'A', b'Z'))
            .union(ByteSet::range(b'0', b'9'))
            .union(ByteSet::single(b'_'))
            .negate(),
        b's' => {
            let mut s = ByteSet::single(b' ');
            for b in [b'\t', b'\n', b'\r', 0x0B, 0x0C] {
                s.insert(b);
            }
            s
        }
        b'S' => {
            let mut s = ByteSet::single(b' ');
            for b in [b'\t', b'\n', b'\r', 0x0B, 0x0C] {
                s.insert(b);
            }
            s.negate()
        }
        // Punctuation escapes: identity.
        c if !c.is_ascii_alphanumeric() => ByteSet::single(c),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byteset_ops() {
        let s = ByteSet::range(b'a', b'c');
        assert!(s.contains(b'a') && s.contains(b'c') && !s.contains(b'd'));
        let n = s.negate();
        assert!(!n.contains(b'b') && n.contains(b'z'));
        assert_eq!(ByteSet::single(b'x').iter().collect::<Vec<_>>(), vec![b'x']);
    }

    #[test]
    fn parse_simple() {
        assert!(matches!(parse_regex("a").unwrap(), RegexAst::Class(_)));
        assert!(matches!(parse_regex("ab|c").unwrap(), RegexAst::Alt(_)));
        assert!(matches!(parse_regex("a*").unwrap(), RegexAst::Star(_)));
    }

    #[test]
    fn parse_counted() {
        match parse_regex("a{2,5}").unwrap() {
            RegexAst::Repeat(_, 2, 5) => {}
            other => panic!("{other:?}"),
        }
        match parse_regex("a{3}").unwrap() {
            RegexAst::Repeat(_, 3, 3) => {}
            other => panic!("{other:?}"),
        }
        match parse_regex("a{3,}").unwrap() {
            RegexAst::Repeat(_, 3, usize::MAX) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn literal_brace_not_counted() {
        // "{" not followed by a valid count is a literal.
        let ast = parse_regex("a{b").unwrap();
        assert!(matches!(ast, RegexAst::Concat(_)));
    }

    #[test]
    fn class_with_escapes() {
        let ast = parse_regex(r"[\d\-x]").unwrap();
        match ast {
            RegexAst::Class(s) => {
                assert!(s.contains(b'5') && s.contains(b'-') && s.contains(b'x'));
                assert!(!s.contains(b'a'));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn class_leading_bracket() {
        // []] — ']' first in class is literal.
        let ast = parse_regex(r"[]a]").unwrap();
        match ast {
            RegexAst::Class(s) => assert!(s.contains(b']') && s.contains(b'a')),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_bad() {
        assert!(parse_regex("(a").is_err());
        assert!(parse_regex("[a").is_err());
        assert!(parse_regex("*a").is_err());
        assert!(parse_regex("a\\").is_err());
    }

    #[test]
    fn case_fold() {
        let ast = parse_regex("aB").unwrap().case_insensitive();
        // Both chars become 2-byte classes.
        match ast {
            RegexAst::Concat(xs) => {
                for x in xs {
                    match x {
                        RegexAst::Class(s) => assert_eq!(s.iter().count(), 2),
                        other => panic!("{other:?}"),
                    }
                }
            }
            other => panic!("{other:?}"),
        }
    }
}
