//! DFA: subset construction over byte equivalence classes, Hopcroft
//! minimisation, and live-state analysis (Definition 9 of the paper).
//!
//! Transitions are a dense `num_states × num_classes` table where the 256
//! input bytes are first mapped to equivalence classes (bytes that behave
//! identically in every transition of the NFA), keeping tables small.
//! A missing transition is the sentinel [`DEAD`] — walking into `DEAD`
//! corresponds to leaving `live(Q)` permanently.

use super::nfa::Nfa;
use std::collections::HashMap;

/// Sentinel "dead sink" state id.
pub const DEAD: u32 = u32::MAX;

/// Deterministic finite automaton over bytes.
#[derive(Debug, Clone)]
pub struct Dfa {
    /// Byte → equivalence class.
    byte_class: [u16; 256],
    num_classes: u16,
    /// `trans[state * num_classes + class]`, `DEAD` when absent.
    trans: Vec<u32>,
    accept: Vec<bool>,
    live: Vec<bool>,
    start: u32,
}

impl Dfa {
    /// Subset construction from an ε-NFA.
    pub fn from_nfa(nfa: &Nfa) -> Dfa {
        Dfa::from_nfa_bounded(nfa, usize::MAX)
            .expect("unbounded subset construction cannot hit the cap")
    }

    /// Subset construction with a hard cap on determinised states.
    ///
    /// Subset construction is worst-case exponential in NFA size, so for
    /// untrusted patterns the cap is checked *inside* the worklist loop —
    /// a hostile regex fails fast instead of growing `sets` without bound.
    pub fn from_nfa_bounded(nfa: &Nfa, max_states: usize) -> Result<Dfa, String> {
        // --- byte equivalence classes ------------------------------------
        // Two bytes are equivalent if every NFA transition set treats them
        // identically. Build a signature per byte from the set memberships.
        let mut sigs: Vec<Vec<bool>> = vec![Vec::new(); 256];
        for st in &nfa.states {
            for (set, _) in &st.trans {
                for (b, sig) in sigs.iter_mut().enumerate() {
                    sig.push(set.contains(b as u8));
                }
            }
        }
        let mut byte_class = [0u16; 256];
        let mut class_of_sig: HashMap<&[bool], u16> = HashMap::new();
        let mut class_repr: Vec<u8> = Vec::new();
        for b in 0..256usize {
            let sig = sigs[b].as_slice();
            let next_id = class_of_sig.len() as u16;
            let id = *class_of_sig.entry(sig).or_insert_with(|| {
                class_repr.push(b as u8);
                next_id
            });
            byte_class[b] = id;
        }
        let num_classes = class_repr.len() as u16;

        // --- subset construction -----------------------------------------
        let mut start_set = vec![nfa.start];
        nfa.eps_closure(&mut start_set);
        let mut state_ids: HashMap<Vec<u32>, u32> = HashMap::new();
        state_ids.insert(start_set.clone(), 0);
        let mut worklist = vec![start_set.clone()];
        let mut sets: Vec<Vec<u32>> = vec![start_set];
        let mut trans: Vec<u32> = Vec::new();

        while let Some(cur) = worklist.pop() {
            let cur_id = state_ids[&cur];
            let need = (cur_id as usize + 1) * num_classes as usize;
            if trans.len() < need {
                trans.resize(need, DEAD);
            }
            for class in 0..num_classes {
                let repr = class_repr[class as usize];
                let mut nxt: Vec<u32> = Vec::new();
                for &s in &cur {
                    for (set, t) in &nfa.states[s as usize].trans {
                        if set.contains(repr) {
                            nxt.push(*t);
                        }
                    }
                }
                if nxt.is_empty() {
                    continue;
                }
                nfa.eps_closure(&mut nxt);
                let nid = match state_ids.get(&nxt) {
                    Some(&id) => id,
                    None => {
                        if sets.len() >= max_states {
                            return Err(format!(
                                "regex DFA exceeds {max_states} states during subset construction"
                            ));
                        }
                        let id = sets.len() as u32;
                        state_ids.insert(nxt.clone(), id);
                        sets.push(nxt.clone());
                        worklist.push(nxt);
                        id
                    }
                };
                trans[cur_id as usize * num_classes as usize + class as usize] = nid;
            }
        }
        trans.resize(sets.len() * num_classes as usize, DEAD);
        let accept: Vec<bool> =
            sets.iter().map(|s| s.contains(&nfa.accept)).collect();

        let mut dfa = Dfa {
            byte_class,
            num_classes,
            trans,
            accept,
            live: Vec::new(),
            start: 0,
        };
        dfa.compute_live();
        Ok(dfa)
    }

    /// Live states (Definition 9): states from which some accept state is
    /// reachable. Computed by reverse BFS from accepting states.
    fn compute_live(&mut self) {
        let n = self.accept.len();
        let mut rev: Vec<Vec<u32>> = vec![Vec::new(); n];
        for s in 0..n {
            for c in 0..self.num_classes as usize {
                let t = self.trans[s * self.num_classes as usize + c];
                if t != DEAD {
                    rev[t as usize].push(s as u32);
                }
            }
        }
        let mut live = vec![false; n];
        let mut stack: Vec<u32> = (0..n as u32).filter(|&s| self.accept[s as usize]).collect();
        for &s in &stack {
            live[s as usize] = true;
        }
        while let Some(s) = stack.pop() {
            for &p in &rev[s as usize] {
                if !live[p as usize] {
                    live[p as usize] = true;
                    stack.push(p);
                }
            }
        }
        self.live = live;
    }

    /// Hopcroft minimisation (plus unreachable-state removal).
    pub fn minimise(&self) -> Dfa {
        let n = self.accept.len();
        let nc = self.num_classes as usize;
        // Partition refinement. Initial blocks: accept / non-accept.
        let mut block_of: Vec<u32> = (0..n).map(|s| self.accept[s] as u32).collect();
        let mut num_blocks: u32 = if self.accept.iter().any(|&a| a) && self.accept.iter().any(|&a| !a) {
            2
        } else {
            1
        };
        if num_blocks == 1 {
            // normalise block ids
            for b in block_of.iter_mut() {
                *b = 0;
            }
        }
        loop {
            // Signature of each state: (block, [block of successor per class])
            let mut sig_map: HashMap<(u32, Vec<u32>), u32> = HashMap::new();
            let mut new_block = vec![0u32; n];
            for s in 0..n {
                let succ: Vec<u32> = (0..nc)
                    .map(|c| {
                        let t = self.trans[s * nc + c];
                        if t == DEAD {
                            u32::MAX
                        } else {
                            block_of[t as usize]
                        }
                    })
                    .collect();
                let key = (block_of[s], succ);
                let next_id = sig_map.len() as u32;
                let id = *sig_map.entry(key).or_insert(next_id);
                new_block[s] = id;
            }
            let nb = sig_map.len() as u32;
            if nb == num_blocks {
                break;
            }
            num_blocks = nb;
            block_of = new_block;
        }

        // Build the quotient automaton, keeping only states reachable from
        // the start block.
        let start_block = block_of[self.start as usize];
        let mut remap: Vec<u32> = vec![DEAD; num_blocks as usize];
        let mut order: Vec<u32> = Vec::new();
        remap[start_block as usize] = 0;
        order.push(start_block);
        let mut qi = 0;
        let mut new_trans: Vec<u32> = Vec::new();
        // representative state per block
        let mut repr: Vec<u32> = vec![DEAD; num_blocks as usize];
        for s in 0..n {
            let b = block_of[s] as usize;
            if repr[b] == DEAD {
                repr[b] = s as u32;
            }
        }
        while qi < order.len() {
            let blk = order[qi];
            qi += 1;
            let s = repr[blk as usize] as usize;
            for c in 0..nc {
                let t = self.trans[s * nc + c];
                let nt = if t == DEAD {
                    DEAD
                } else {
                    let tb = block_of[t as usize];
                    if remap[tb as usize] == DEAD {
                        remap[tb as usize] = order.len() as u32;
                        order.push(tb);
                    }
                    remap[tb as usize]
                };
                new_trans.push(nt);
            }
        }
        let accept: Vec<bool> =
            order.iter().map(|&b| self.accept[repr[b as usize] as usize]).collect();
        let mut out = Dfa {
            byte_class: self.byte_class,
            num_classes: self.num_classes,
            trans: new_trans,
            accept,
            live: Vec::new(),
            start: 0,
        };
        out.compute_live();
        out
    }

    /// Start state.
    #[inline]
    pub fn start(&self) -> u32 {
        self.start
    }

    /// Number of states (excluding the implicit dead sink).
    pub fn num_states(&self) -> usize {
        self.accept.len()
    }

    /// Equivalence class of a byte (see [`Dfa::num_classes`]).
    #[inline]
    pub fn byte_class(&self, byte: u8) -> u16 {
        self.byte_class[byte as usize]
    }

    /// Number of byte equivalence classes. Bytes in the same class take
    /// identical transitions from *every* state, so per-class work (one
    /// step shared by all sibling bytes of a class, dead-class analysis)
    /// is sound by construction.
    #[inline]
    pub fn num_classes(&self) -> usize {
        self.num_classes as usize
    }

    /// One transition by pre-resolved byte class. `state` must not be
    /// `DEAD` and `class` must be `< num_classes()`.
    #[inline]
    pub fn step_class(&self, state: u32, class: u16) -> u32 {
        self.trans[state as usize * self.num_classes as usize + class as usize]
    }

    /// One transition. `DEAD` in/out represents the dead sink.
    #[inline]
    pub fn step(&self, state: u32, byte: u8) -> u32 {
        if state == DEAD {
            return DEAD;
        }
        self.trans[state as usize * self.num_classes as usize
            + self.byte_class[byte as usize] as usize]
    }

    /// Walk a byte string from `state`.
    #[inline]
    pub fn walk(&self, mut state: u32, input: &[u8]) -> u32 {
        for &b in input {
            state = self.step(state, b);
            if state == DEAD {
                return DEAD;
            }
        }
        state
    }

    /// Is `state` accepting? (`DEAD` is not.)
    #[inline]
    pub fn is_accept(&self, state: u32) -> bool {
        state != DEAD && self.accept[state as usize]
    }

    /// Is `state` live (Definition 9)? (`DEAD` is not.)
    #[inline]
    pub fn is_live(&self, state: u32) -> bool {
        state != DEAD && self.live[state as usize]
    }

    /// Does the DFA accept exactly this string?
    pub fn accepts(&self, input: &[u8]) -> bool {
        self.is_accept(self.walk(self.start, input))
    }

    /// True when some string is accepted (start state is live).
    pub fn language_nonempty(&self) -> bool {
        self.is_live(self.start)
    }

    /// True when the empty string is accepted.
    pub fn accepts_empty(&self) -> bool {
        self.is_accept(self.start)
    }

    /// Shortest accepted string, if any (BFS) — used by dataset generators
    /// and for grammar sanity checks.
    pub fn shortest_accepted(&self) -> Option<Vec<u8>> {
        if !self.language_nonempty() {
            return None;
        }
        let mut prev: Vec<Option<(u32, u8)>> = vec![None; self.num_states()];
        let mut visited = vec![false; self.num_states()];
        let mut queue = std::collections::VecDeque::new();
        visited[self.start as usize] = true;
        queue.push_back(self.start);
        while let Some(s) = queue.pop_front() {
            if self.is_accept(s) {
                // Reconstruct.
                let mut bytes = Vec::new();
                let mut cur = s;
                while let Some((p, b)) = prev[cur as usize] {
                    bytes.push(b);
                    cur = p;
                }
                bytes.reverse();
                return Some(bytes);
            }
            for byte in 0..=255u8 {
                let t = self.step(s, byte);
                if t != DEAD && !visited[t as usize] {
                    visited[t as usize] = true;
                    prev[t as usize] = Some((s, byte));
                    queue.push_back(t);
                }
            }
        }
        None
    }

    /// All bytes with a non-dead transition out of `state`.
    pub fn out_bytes(&self, state: u32) -> Vec<u8> {
        (0..=255u8).filter(|&b| self.step(state, b) != DEAD).collect()
    }

    /// Static dead-byte analysis, per class: `true` at class `c` when the
    /// transition on `c` is `DEAD` from *every live* state. A walk that is
    /// still in a live state dies on such a byte unconditionally, so a
    /// mask-store build may prune the token — and every token sharing the
    /// prefix — without executing the step.
    pub fn dead_classes(&self) -> Vec<bool> {
        let nc = self.num_classes as usize;
        let mut dead = vec![true; nc];
        for (s, &live) in self.live.iter().enumerate() {
            if !live {
                continue;
            }
            for (c, d) in dead.iter_mut().enumerate() {
                if *d && self.trans[s * nc + c] != DEAD {
                    *d = false;
                }
            }
        }
        dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::ast::parse_regex;
    use crate::regex::nfa::Nfa;

    fn dfa(pat: &str) -> Dfa {
        Dfa::from_nfa(&Nfa::from_ast(&parse_regex(pat).unwrap())).minimise()
    }

    #[test]
    fn classic_minimisation() {
        // (a|b)*abb — minimal DFA has 4 states.
        let d = dfa("(a|b)*abb");
        assert_eq!(d.num_states(), 4);
        assert!(d.accepts(b"abb"));
        assert!(d.accepts(b"aababb"));
        assert!(!d.accepts(b"ab"));
    }

    #[test]
    fn dead_transitions() {
        let d = dfa("ab");
        let q = d.walk(d.start(), b"a");
        assert!(d.is_live(q));
        assert_eq!(d.step(q, b'x'), DEAD);
        assert_eq!(d.walk(DEAD, b"anything"), DEAD);
    }

    #[test]
    fn live_analysis() {
        let d = dfa("[0-9]+");
        assert!(d.is_live(d.start()));
        assert!(!d.accepts_empty());
        let q = d.walk(d.start(), b"12");
        assert!(d.is_accept(q) && d.is_live(q));
    }

    #[test]
    fn shortest_accepted() {
        assert_eq!(dfa("abc").shortest_accepted().unwrap(), b"abc");
        assert_eq!(dfa("x+").shortest_accepted().unwrap(), b"x");
        let s = dfa("[0-9]{3}").shortest_accepted().unwrap();
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn empty_string_language() {
        let d = dfa("a*");
        assert!(d.accepts_empty());
        assert!(d.accepts(b""));
    }

    #[test]
    fn out_bytes() {
        let d = dfa("[ab]c");
        let outs = d.out_bytes(d.start());
        assert_eq!(outs, vec![b'a', b'b']);
    }

    #[test]
    fn equivalence_classes_compress() {
        let d = dfa("[a-z]+");
        // 26 letters behave identically → far fewer classes than 256.
        assert!(d.num_classes as usize <= 4);
    }

    #[test]
    fn step_class_agrees_with_step() {
        let d = dfa("(a|b)*abb");
        for q in 0..d.num_states() as u32 {
            for b in 0..=255u8 {
                assert_eq!(d.step_class(q, d.byte_class(b)), d.step(q, b));
            }
        }
    }

    #[test]
    fn dead_classes_match_per_state_transitions() {
        let d = dfa("[0-9]+");
        let dead = d.dead_classes();
        assert_eq!(dead.len(), d.num_classes());
        for b in 0..=255u8 {
            let dies_everywhere = (0..d.num_states() as u32)
                .filter(|&q| d.is_live(q))
                .all(|q| d.step(q, b) == DEAD);
            assert_eq!(
                dead[d.byte_class(b) as usize],
                dies_everywhere,
                "byte {b:#x}"
            );
        }
        // Digits are never dead; letters are dead from every state.
        assert!(!dead[d.byte_class(b'5') as usize]);
        assert!(dead[d.byte_class(b'q') as usize]);
    }
}
