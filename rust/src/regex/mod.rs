//! Regex engine: parser → Thompson NFA → subset-construction DFA →
//! Hopcroft minimisation, with live-state analysis (Definition 9).
//!
//! Grammar terminals (Definition 1 in the paper) are described by regular
//! expressions in Lark's `/.../` syntax; the DFA mask store (§4.3) needs
//! direct access to DFA states, transitions, final states, and *live*
//! states, so a from-scratch engine is required — crates.io regex engines do
//! not expose their automata in a usable way and are unavailable offline
//! anyway.
//!
//! The alphabet is **bytes** (Σ = 0..=255). Unicode inputs work because
//! UTF-8 byte sequences flow through byte-level automata; character classes
//! beyond ASCII match individual bytes (sufficient for the grammars used
//! here, whose terminals are ASCII-structured).

mod ast;
mod dfa;
mod nfa;

pub use ast::{parse_regex, RegexAst, RegexError};
pub use dfa::{Dfa, DEAD};
pub use nfa::Nfa;

/// Compile a regex (Lark `/.../` body, flags already stripped) to a
/// minimised DFA with live-state analysis.
pub fn compile(pattern: &str, ignore_case: bool) -> Result<Dfa, RegexError> {
    compile_bounded(pattern, ignore_case, usize::MAX, usize::MAX)
}

/// [`compile`] with hard resource caps, for untrusted patterns.
///
/// `max_nfa_states` bounds the Thompson expansion (estimated from the AST
/// *before* the NFA is allocated, so counted-repeat bombs never reach the
/// allocator); `max_dfa_states` bounds subset construction, which is
/// worst-case exponential in NFA size. Either overflow is a clean error.
pub fn compile_bounded(
    pattern: &str,
    ignore_case: bool,
    max_nfa_states: usize,
    max_dfa_states: usize,
) -> Result<Dfa, RegexError> {
    let ast = parse_regex(pattern)?;
    let ast = if ignore_case { ast.case_insensitive() } else { ast };
    let est = ast.nfa_size_estimate();
    if est > max_nfa_states {
        return Err(RegexError {
            pos: 0,
            msg: format!("regex expands to ~{est} NFA states (limit {max_nfa_states})"),
        });
    }
    let nfa = Nfa::from_ast(&ast);
    let dfa = Dfa::from_nfa_bounded(&nfa, max_dfa_states)
        .map_err(|msg| RegexError { pos: 0, msg })?;
    Ok(dfa.minimise())
}

/// Compile a *literal string* terminal (e.g. the anonymous `"("` terminal)
/// to a DFA without regex interpretation.
pub fn compile_literal(lit: &[u8]) -> Dfa {
    let ast = RegexAst::Literal(lit.to_vec());
    let nfa = Nfa::from_ast(&ast);
    Dfa::from_nfa(&nfa).minimise()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accepts(dfa: &Dfa, s: &str) -> bool {
        dfa.accepts(s.as_bytes())
    }

    #[test]
    fn literal_dfa() {
        let d = compile_literal(b"def");
        assert!(accepts(&d, "def"));
        assert!(!accepts(&d, "de"));
        assert!(!accepts(&d, "defx"));
        assert!(!accepts(&d, ""));
    }

    #[test]
    fn int_regex() {
        let d = compile("[0-9]+", false).unwrap();
        assert!(accepts(&d, "0"));
        assert!(accepts(&d, "123456"));
        assert!(!accepts(&d, ""));
        assert!(!accepts(&d, "12a"));
    }

    #[test]
    fn float_regex() {
        let d = compile(r"[0-9]+\.[0-9]+", false).unwrap();
        assert!(accepts(&d, "3.14"));
        assert!(!accepts(&d, "3."));
        assert!(!accepts(&d, ".5"));
    }

    #[test]
    fn alternation_and_groups() {
        let d = compile("(ab|cd)*e?", false).unwrap();
        assert!(accepts(&d, ""));
        assert!(accepts(&d, "abcdab"));
        assert!(accepts(&d, "abe"));
        assert!(!accepts(&d, "a"));
    }

    #[test]
    fn char_classes() {
        let d = compile(r"[a-zA-Z_]\w*", false).unwrap();
        assert!(accepts(&d, "_name1"));
        assert!(accepts(&d, "Xy_9"));
        assert!(!accepts(&d, "9x"));
    }

    #[test]
    fn negated_class() {
        let d = compile(r#""[^"]*""#, false).unwrap();
        assert!(accepts(&d, "\"hello\""));
        assert!(accepts(&d, "\"\""));
        assert!(!accepts(&d, "\"a\"b\""));
    }

    #[test]
    fn counted_repetition() {
        let d = compile(r"[0-9]{2}", false).unwrap();
        assert!(accepts(&d, "42"));
        assert!(!accepts(&d, "4"));
        assert!(!accepts(&d, "423"));
        let d = compile(r"a{1,3}", false).unwrap();
        assert!(accepts(&d, "a"));
        assert!(accepts(&d, "aaa"));
        assert!(!accepts(&d, "aaaa"));
        let d = compile(r"a{2,}", false).unwrap();
        assert!(!accepts(&d, "a"));
        assert!(accepts(&d, "aaaaa"));
    }

    #[test]
    fn dot_excludes_newline() {
        let d = compile("a.b", false).unwrap();
        assert!(accepts(&d, "axb"));
        assert!(!accepts(&d, "a\nb"));
    }

    #[test]
    fn case_insensitive() {
        let d = compile("select", true).unwrap();
        assert!(accepts(&d, "SELECT"));
        assert!(accepts(&d, "SeLeCt"));
        assert!(!accepts(&d, "selec"));
    }

    #[test]
    fn escapes() {
        let d = compile(r"\d+\.\d+", false).unwrap();
        assert!(accepts(&d, "1.25"));
        let d = compile(r"\(\)", false).unwrap();
        assert!(accepts(&d, "()"));
        let d = compile(r"a\|b", false).unwrap();
        assert!(accepts(&d, "a|b"));
        assert!(!accepts(&d, "a"));
    }

    #[test]
    fn live_states_definition9() {
        // int DFA of Fig. 6: start live, accept live; dead sink not live.
        let d = compile("[0-9]+", false).unwrap();
        let q0 = d.start();
        assert!(d.is_live(q0));
        let q1 = d.step(q0, b'5');
        assert!(d.is_live(q1) && d.is_accept(q1));
        let dead = d.step(q1, b'x');
        assert_eq!(dead, DEAD);
    }

    #[test]
    fn walk_partial_stays_live() {
        let d = compile(r"[0-9]+\.[0-9]+", false).unwrap();
        // "2." is a prefix of a float: walking it must stay live, not accept.
        let q = d.walk(d.start(), b"2.");
        assert_ne!(q, DEAD);
        assert!(d.is_live(q));
        assert!(!d.is_accept(q));
    }

    #[test]
    fn minimisation_preserves_language() {
        use crate::util::prop;
        use crate::util::rng::Rng;
        let ast = parse_regex("(a|b)*abb").unwrap();
        let nfa = Nfa::from_ast(&ast);
        let big = Dfa::from_nfa(&nfa);
        let small = big.minimise();
        assert!(small.num_states() <= big.num_states());
        let mut rng = Rng::new(17);
        for _ in 0..500 {
            let s = prop::ascii_string(&mut rng, b"ab", 12);
            assert_eq!(
                big.accepts(s.as_bytes()),
                small.accepts(s.as_bytes()),
                "disagree on {s:?}"
            );
        }
    }

    #[test]
    fn nongreedy_treated_as_greedy_language() {
        // .*? has the same *language* as .* — documented behaviour.
        let d = compile(r#"".*?""#, false).unwrap();
        assert!(accepts(&d, "\"abc\""));
    }

    #[test]
    fn anchors_rejected() {
        assert!(parse_regex("^abc$").is_err());
    }

    #[test]
    fn bounded_compile_matches_unbounded_on_sane_patterns() {
        for pat in ["[0-9]+", r#""[^"]*""#, "(a|b)*abb", "a{2,5}"] {
            let loose = compile(pat, false).unwrap();
            let tight = compile_bounded(pat, false, 10_000, 10_000).unwrap();
            assert_eq!(loose.num_states(), tight.num_states(), "{pat}");
        }
    }

    #[test]
    fn nfa_bomb_rejected_before_allocation() {
        // Nested counted repeats multiply the Thompson expansion per level;
        // the AST estimate must reject this without building the NFA.
        let pat = "((((a{64}){64}){64}){64})";
        let err = compile_bounded(pat, false, 100_000, 100_000).unwrap_err();
        assert!(err.msg.contains("NFA states"), "{err}");
    }

    #[test]
    fn dfa_blowup_rejected_inside_subset_construction() {
        // (a|b)*a(a|b){N} determinises to ≥ 2^N states — the classic
        // subset-construction bomb. Small NFA, huge DFA: only the in-loop
        // cap catches it.
        let pat = "(a|b)*a(a|b){20}";
        let err = compile_bounded(pat, false, 100_000, 4_096).unwrap_err();
        assert!(err.msg.contains("subset construction"), "{err}");
        // The same pattern with a generous cap still compiles.
        assert!(compile_bounded("(a|b)*a(a|b){8}", false, 100_000, 4_096).is_ok());
    }

    #[test]
    fn size_estimate_is_saturating() {
        let ast = parse_regex("((((((a{64}){64}){64}){64}){64}){64})").unwrap();
        // Must not overflow; must be astronomically large.
        assert!(ast.nfa_size_estimate() > 1 << 40);
    }
}
