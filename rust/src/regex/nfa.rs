//! Thompson construction: regex AST → ε-NFA.

use super::ast::{ByteSet, RegexAst};

/// One NFA state: ε-successors plus byte-class transitions.
#[derive(Debug, Default, Clone)]
pub struct NfaState {
    pub eps: Vec<u32>,
    pub trans: Vec<(ByteSet, u32)>,
}

/// ε-NFA with a single start and single accept state.
#[derive(Debug)]
pub struct Nfa {
    pub states: Vec<NfaState>,
    pub start: u32,
    pub accept: u32,
}

impl Nfa {
    /// Thompson construction.
    pub fn from_ast(ast: &RegexAst) -> Nfa {
        let mut b = Builder { states: Vec::new() };
        let (s, a) = b.build(ast);
        Nfa { states: b.states, start: s, accept: a }
    }

    /// ε-closure of a set of states (sorted, deduped).
    pub fn eps_closure(&self, set: &mut Vec<u32>) {
        let mut stack: Vec<u32> = set.clone();
        let mut seen: Vec<bool> = vec![false; self.states.len()];
        for &s in set.iter() {
            seen[s as usize] = true;
        }
        while let Some(s) = stack.pop() {
            for &t in &self.states[s as usize].eps {
                if !seen[t as usize] {
                    seen[t as usize] = true;
                    set.push(t);
                    stack.push(t);
                }
            }
        }
        set.sort_unstable();
        set.dedup();
    }
}

struct Builder {
    states: Vec<NfaState>,
}

impl Builder {
    fn fresh(&mut self) -> u32 {
        self.states.push(NfaState::default());
        (self.states.len() - 1) as u32
    }

    fn eps(&mut self, from: u32, to: u32) {
        self.states[from as usize].eps.push(to);
    }

    fn edge(&mut self, from: u32, set: ByteSet, to: u32) {
        self.states[from as usize].trans.push((set, to));
    }

    /// Build a fragment; returns (start, accept).
    fn build(&mut self, ast: &RegexAst) -> (u32, u32) {
        match ast {
            RegexAst::Empty => {
                let s = self.fresh();
                let a = self.fresh();
                self.eps(s, a);
                (s, a)
            }
            RegexAst::Class(set) => {
                let s = self.fresh();
                let a = self.fresh();
                self.edge(s, *set, a);
                (s, a)
            }
            RegexAst::Literal(bytes) => {
                let s = self.fresh();
                let mut cur = s;
                for &b in bytes {
                    let nxt = self.fresh();
                    self.edge(cur, ByteSet::single(b), nxt);
                    cur = nxt;
                }
                (s, cur)
            }
            RegexAst::Concat(parts) => {
                let mut frags = parts.iter().map(|p| self.build(p)).collect::<Vec<_>>();
                if frags.is_empty() {
                    return self.build(&RegexAst::Empty);
                }
                let (s, mut a) = frags.remove(0);
                for (ns, na) in frags {
                    self.eps(a, ns);
                    a = na;
                }
                (s, a)
            }
            RegexAst::Alt(branches) => {
                let s = self.fresh();
                let a = self.fresh();
                for br in branches {
                    let (bs, ba) = self.build(br);
                    self.eps(s, bs);
                    self.eps(ba, a);
                }
                (s, a)
            }
            RegexAst::Star(inner) => {
                let s = self.fresh();
                let a = self.fresh();
                let (is, ia) = self.build(inner);
                self.eps(s, is);
                self.eps(s, a);
                self.eps(ia, is);
                self.eps(ia, a);
                (s, a)
            }
            RegexAst::Plus(inner) => {
                let (is, ia) = self.build(inner);
                let a = self.fresh();
                self.eps(ia, a);
                self.eps(ia, is);
                (is, a)
            }
            RegexAst::Opt(inner) => {
                let s = self.fresh();
                let a = self.fresh();
                let (is, ia) = self.build(inner);
                self.eps(s, is);
                self.eps(s, a);
                self.eps(ia, a);
                (s, a)
            }
            RegexAst::Repeat(inner, lo, hi) => {
                // Expand bounded repetition; cap expansion to keep automata
                // small (grammar terminals use small counts like {2} {4}).
                const CAP: usize = 64;
                let lo = *lo;
                let hi = *hi;
                let mut parts: Vec<RegexAst> = Vec::new();
                for _ in 0..lo.min(CAP) {
                    parts.push((**inner).clone());
                }
                if hi == usize::MAX {
                    parts.push(RegexAst::Star(inner.clone()));
                } else {
                    for _ in lo..hi.min(CAP) {
                        parts.push(RegexAst::Opt(inner.clone()));
                    }
                }
                self.build(&RegexAst::Concat(parts))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::regex::ast::parse_regex;

    fn nfa_accepts(nfa: &Nfa, input: &[u8]) -> bool {
        let mut cur = vec![nfa.start];
        nfa.eps_closure(&mut cur);
        for &b in input {
            let mut nxt = Vec::new();
            for &s in &cur {
                for (set, t) in &nfa.states[s as usize].trans {
                    if set.contains(b) {
                        nxt.push(*t);
                    }
                }
            }
            nfa.eps_closure(&mut nxt);
            cur = nxt;
            if cur.is_empty() {
                return false;
            }
        }
        cur.contains(&nfa.accept)
    }

    #[test]
    fn thompson_basic() {
        let nfa = Nfa::from_ast(&parse_regex("(a|b)*c").unwrap());
        assert!(nfa_accepts(&nfa, b"c"));
        assert!(nfa_accepts(&nfa, b"ababc"));
        assert!(!nfa_accepts(&nfa, b"ab"));
    }

    #[test]
    fn plus_requires_one() {
        let nfa = Nfa::from_ast(&parse_regex("a+").unwrap());
        assert!(!nfa_accepts(&nfa, b""));
        assert!(nfa_accepts(&nfa, b"aaa"));
    }

    #[test]
    fn literal_fragment() {
        let nfa = Nfa::from_ast(&RegexAst::Literal(b"if".to_vec()));
        assert!(nfa_accepts(&nfa, b"if"));
        assert!(!nfa_accepts(&nfa, b"i"));
    }

    #[test]
    fn eps_closure_dedup() {
        let nfa = Nfa::from_ast(&parse_regex("(a?)*").unwrap());
        let mut set = vec![nfa.start];
        nfa.eps_closure(&mut set);
        let mut sorted = set.clone();
        sorted.dedup();
        assert_eq!(set.len(), sorted.len());
    }
}
