//! `syncode` CLI — leader entrypoint for the serving stack.
//!
//! Subcommands:
//!
//! - `compile`    compile a grammar artifact offline and write its cache file;
//! - `generate`   one-shot constrained generation (mock or PJRT model);
//!   `--stream` prints each token as it is decoded and validated;
//! - `serve`      run the batch server over a synthetic request stream —
//!   `--grammars a,b,c` serves several grammars from one registry, with
//!   each request routed per-name through a batched decode loop;
//!   `--replicas N` runs N model replicas behind one bounded admission
//!   queue and `--mask-threads M` computes grammar masks on a shared
//!   worker pool, overlapped with the batched decode (`docs/serving.md`);
//!   `--http ADDR` serves the same coordinator over HTTP instead of the
//!   synthetic stream (`POST /v1/generate`, with `?stream=1` for
//!   token-by-token SSE, `POST`/`DELETE /v1/grammars` for request-time
//!   user-supplied grammars, `GET /healthz`, `/metrics`); `--watch DIR`
//!   hot-reloads `*.lark` files from a directory into the registry;
//! - `grammar`    inspect a built-in grammar (terminals, LR tables, conflicts);
//! - `maskstore`  build a DFA mask store and print its statistics (Table 5);
//! - `experiment` run a paper experiment (table1|table2|table3|table4);
//! - `check`      syntax-check a file against a grammar (the oracle).

use std::path::PathBuf;
use std::sync::Arc;
use syncode::artifact::{self, ArtifactConfig, CompiledGrammar, GrammarRegistry, GrammarWatcher};
use syncode::coordinator::{
    Coordinator, CoordinatorConfig, GenParams, GenRequest, Server, SloClass, Strategy,
};
use syncode::engine::GrammarContext;
use syncode::eval::dataset;
use syncode::eval::harness::{self, EngineKind, EvalEnv};
use syncode::grammar::CompileLimits;
use syncode::net::{GrammarApiConfig, HttpConfig, HttpServer};
use syncode::parser::{LrMode, LrTable};
use syncode::runtime::{
    replicate_factory, LanguageModel, MockModel, ModelFactory, PjrtModel, PjrtVariant,
};
use syncode::tokenizer::Tokenizer;
use syncode::util::bench::Table;
use syncode::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("compile") => cmd_compile(&args),
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("grammar") => cmd_grammar(&args),
        Some("maskstore") => cmd_maskstore(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("check") => cmd_check(&args),
        _ => {
            eprintln!(
                "usage: syncode <compile|generate|serve|grammar|maskstore|experiment|check> [--opts]\n\
                 common:   --grammar <json|calc|sql|python|go> --grammars a,b --artifacts <dir>\n\
                 \x20          --cache-dir <dir> --threads <n> --mock\n\
                 generate: --stream   (print tokens as they decode)\n\
                 \x20          --spec-k <k>  (speculative drafts per step; 0 = off)\n\
                 \x20          --priority <interactive|batch>  (admission SLO class)\n\
                 \x20          --deadline-ms <ms>  (per-request deadline; 0 = none)\n\
                 serve:    --replicas <n> --mask-threads <m> --queue-cap <n> --requests <n>\n\
                 \x20          --spec-k <k> --spec-k-cap <k> --deadline-ms <ms>\n\
                 \x20          --batch-queue-cap <n> --batch-age-ms <ms>  (batch-class admission)\n\
                 \x20          --http <addr:port> --http-workers <n>   (HTTP front instead of the batch stream;\n\
                 \x20          POST /v1/generate?stream=1 streams tokens as SSE;\n\
                 \x20          POST/DELETE /v1/grammars registers user-supplied grammars)\n\
                 \x20          --sse-keepalive-ms <ms>  (idle-stream heartbeat; 0 = off)\n\
                 \x20          --watch <dir> --watch-ms <ms>  (hot-reload *.lark files into the registry)\n\
                 \x20          --max-grammar-bytes <n> --max-grammar-rules <n> --max-grammar-terminals <n>\n\
                 \x20          --max-regex-bytes <n> --max-dfa-states <n> --compile-budget-ms <ms>\n\
                 \x20          (untrusted-grammar compile caps for /v1/grammars and --watch)"
            );
            std::process::exit(2);
        }
    }
}

fn params_from(args: &Args) -> GenParams {
    let temp = args.get_num("temperature", 0.7f32);
    let strategy = match args.get_or("strategy", "topp").as_str() {
        "greedy" => Strategy::Greedy,
        "temp" => Strategy::Temperature(temp),
        _ => Strategy::TopP { temp, p: args.get_num("top-p", 0.95f32) },
    };
    let pr = args.get_or("priority", "interactive");
    let slo = SloClass::parse(&pr).unwrap_or_else(|| {
        eprintln!("unknown --priority '{pr}' (interactive|batch)");
        std::process::exit(2);
    });
    GenParams {
        max_new_tokens: args.get_num("max-tokens", 120),
        strategy,
        seed: args.get_num("seed", 7u64),
        opportunistic: !args.flag("no-opportunistic"),
        spec_k: args.get_num("spec-k", 0usize),
        slo,
        // 0 (the default) = no deadline; the wire API says the same
        // thing by omitting the field.
        deadline_ms: match args.get_num("deadline-ms", 0u64) {
            0 => None,
            ms => Some(ms),
        },
    }
}

/// Artifact compile options from the command line.
fn artifact_cfg(args: &Args) -> ArtifactConfig {
    let mut cfg = ArtifactConfig::default();
    cfg.mask.threads = args.get_num("threads", 0usize); // 0 = all cores
    if args.flag("canonical") {
        cfg.lr_mode = LrMode::Canonical;
    }
    if args.flag("no-m1") {
        cfg.mask.with_m1 = false;
    }
    cfg
}

/// `<cache-dir>/<grammar>-<fingerprint>.syncart`; None when no
/// `--cache-dir` was given. The fingerprint (tokenizer + compile options,
/// `artifact::cache_file_name`) keeps different grammar sets — which
/// train different union tokenizers — from overwriting each other's
/// caches on every run (permanent thrash, never warm). The HTTP
/// registration path uses the same helper, so a grammar uploaded over
/// `POST /v1/grammars` warm-loads after a restart.
fn cache_path(
    args: &Args,
    gname: &str,
    tok: &Tokenizer,
    cfg: &ArtifactConfig,
) -> Option<PathBuf> {
    args.get("cache-dir")
        .map(|d| PathBuf::from(d).join(artifact::cache_file_name(gname, tok, cfg)))
}

/// Untrusted-grammar compile caps from the command line; applied to
/// `POST /v1/grammars` and `--watch` compiles (never to the trusted
/// built-in grammars compiled at startup).
fn compile_limits_from(args: &Args) -> CompileLimits {
    let d = CompileLimits::default();
    CompileLimits {
        max_source_bytes: args.get_num("max-grammar-bytes", d.max_source_bytes),
        max_rules: args.get_num("max-grammar-rules", d.max_rules),
        max_terminals: args.get_num("max-grammar-terminals", d.max_terminals),
        max_regex_bytes: args.get_num("max-regex-bytes", d.max_regex_bytes),
        max_nfa_states: d.max_nfa_states,
        max_dfa_states: args.get_num("max-dfa-states", d.max_dfa_states),
        budget_ms: args.get_num("compile-budget-ms", d.budget_ms),
    }
}

/// Compile or warm-load one grammar artifact, reporting which happened.
fn artifact_for(args: &Args, gname: &str, tok: Arc<Tokenizer>) -> Arc<CompiledGrammar> {
    let cfg = artifact_cfg(args);
    match cache_path(args, gname, &tok, &cfg) {
        Some(path) => {
            // A corrupt or unreadable cache that survives load_or_compile's
            // own fall-through (e.g. the recompile also fails) must exit
            // cleanly — an operator typo in --cache-dir is not a crash.
            let (art, hit) = CompiledGrammar::load_or_compile(&path, gname, tok, &cfg)
                .unwrap_or_else(|e| {
                    eprintln!("error: artifact {gname}: {e}");
                    std::process::exit(1);
                });
            let ss = &art.store.stats;
            let how = match (hit, ss.zero_copy, ss.mapped) {
                (true, true, true) => "warm-loaded (zero-copy mmap) from",
                (true, true, false) => "warm-loaded (zero-copy view) from",
                (true, false, _) => "warm-loaded (copy) from",
                (false, ..) => "compiled + cached to",
            };
            eprintln!(
                "[artifact {gname}: {how} {} in {:.2}s]",
                path.display(),
                art.compile_stats.total_secs
            );
            art
        }
        None => CompiledGrammar::compile(gname, tok, &cfg).unwrap_or_else(|e| {
            eprintln!("error: artifact {gname}: {e}");
            std::process::exit(1);
        }),
    }
}

/// The mock-serving tokenizer for a grammar set: BPE trained on the union
/// of the grammars' corpora. `compile`, `generate` and `serve` all share
/// this exact recipe (same defaults for --seed/--merges), so an artifact
/// cache written by one subcommand warm-loads in the others.
fn mock_tokenizer(args: &Args, gnames: &[String]) -> (Arc<Tokenizer>, Vec<Vec<u8>>) {
    let seed = args.get_num("seed", 7u64);
    let merges = args.get_num("merges", 160usize);
    let names: Vec<&str> = gnames.iter().map(String::as_str).collect();
    let (tok, union_docs) = dataset::mock_serving_recipe(&names, 120, seed, merges);
    (Arc::new(tok), union_docs)
}

/// Parse `--grammars a,b` (falling back to `--grammar`) into a non-empty
/// list; exits with a usage error otherwise.
fn grammars_arg(args: &Args, cmd: &str) -> Vec<String> {
    let gnames: Vec<String> = args
        .get_or("grammars", &args.get_or("grammar", "json"))
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if gnames.is_empty() {
        eprintln!("{cmd}: no grammars specified (--grammar json or --grammars json,calc)");
        std::process::exit(2);
    }
    gnames
}

/// The serving tokenizer for a grammar set, plus the mock corpus (empty in
/// AOT mode) and whether the mock model is in play. One shared predicate
/// (`config.json` marks a complete AOT artifacts dir) and one shared mock
/// recipe, so compile/generate/serve agree and caches warm-load across
/// subcommands.
fn serving_tokenizer(args: &Args, gnames: &[String]) -> (Arc<Tokenizer>, Vec<Vec<u8>>, bool) {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let use_mock = args.flag("mock") || !dir.join("config.json").exists();
    if use_mock {
        let (tok, docs) = mock_tokenizer(args, gnames);
        (tok, docs, true)
    } else {
        let tok = Arc::new(
            Tokenizer::from_file(&dir.join("tokenizer.json")).expect("tokenizer.json"),
        );
        (tok, Vec::new(), false)
    }
}

/// One factory per replica; each runs inside its own scheduler thread
/// (mock replicas share the corpus recipe, PJRT replicas each load the
/// same artifacts dir).
fn model_factories(
    args: &Args,
    use_mock: bool,
    tok: &Arc<Tokenizer>,
    docs: &[Vec<u8>],
    replicas: usize,
) -> Vec<ModelFactory> {
    if use_mock {
        eprintln!("[model: mock-bigram — pass --artifacts or run `make artifacts` for PJRT]");
        let lanes = args.get_num("lanes", 2usize);
        let tok = tok.clone();
        let docs = docs.to_vec();
        replicate_factory(replicas, move || {
            Ok(Box::new(MockModel::from_documents(tok.clone(), &docs, lanes, 512, 11))
                as Box<dyn LanguageModel>)
        })
    } else {
        let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
        let variant = if args.flag("full-recompute") {
            PjrtVariant::FullRecompute
        } else {
            PjrtVariant::KvCache
        };
        replicate_factory(replicas, move || {
            Ok(Box::new(PjrtModel::load(&dir, variant)?) as Box<dyn LanguageModel>)
        })
    }
}

/// Single-replica convenience (`generate`).
fn model_factory(
    args: &Args,
    use_mock: bool,
    tok: Arc<Tokenizer>,
    docs: Vec<Vec<u8>>,
) -> ModelFactory {
    model_factories(args, use_mock, &tok, &docs, 1).pop().expect("one factory")
}

/// Compact count for walk-step columns: `1234` → "1.2k", `0` → "0".
fn fmt_count(n: u64) -> String {
    match n {
        0..=9_999 => n.to_string(),
        10_000..=9_999_999 => format!("{:.1}k", n as f64 / 1e3),
        _ => format!("{:.1}M", n as f64 / 1e6),
    }
}

fn cmd_compile(args: &Args) {
    // Accepts the same --grammars list as `serve`: the artifact set must
    // target the *serving* tokenizer, and in mock mode that tokenizer is
    // trained on the union of the listed grammars' corpora — so compile
    // and a later serve over the same list agree and the cache warm-loads.
    let gnames = grammars_arg(args, "compile");
    let (tok, _, _) = serving_tokenizer(args, &gnames);
    let cfg = artifact_cfg(args);
    let cache_dir = args.get_or("cache-dir", "artifacts/grammar-cache");

    // New columns go at the END: ci.sh's full-tier gate awks the "cached"
    // and "store(s)" columns by position.
    let mut t = Table::new(&[
        "grammar", "|V|", "|Q|", "threads", "cached", "load", "grammar(s)", "tables(s)",
        "store(s)", "total(s)", "blob", "steps", "÷naive",
    ]);
    for gname in &gnames {
        let out =
            PathBuf::from(&cache_dir).join(artifact::cache_file_name(gname, &tok, &cfg));
        let (art, hit) =
            CompiledGrammar::load_or_compile(&out, gname, tok.clone(), &cfg)
                .unwrap_or_else(|e| {
                    eprintln!("error: compile {gname}: {e}");
                    std::process::exit(1);
                });
        let blob_len =
            std::fs::metadata(&out).map(|m| m.len() as usize).unwrap_or(0);
        let cs = &art.compile_stats;
        let ss = &art.store.stats;
        t.row(&[
            gname.clone(),
            ss.vocab_size.to_string(),
            ss.num_dfa_states.to_string(),
            ss.build_threads.to_string(),
            if hit { "warm" } else { "cold" }.to_string(),
            match (ss.zero_copy, ss.mapped) {
                (true, true) => "mmap",
                (true, false) => "view",
                _ => "copy",
            }
            .to_string(),
            format!("{:.3}", cs.grammar_secs),
            format!("{:.3}", cs.table_secs),
            format!("{:.3}", cs.store_secs),
            format!("{:.3}", cs.total_secs),
            format!("{:.2}MB", blob_len as f64 / 1e6),
            // Trie-walk counters exist only for cold builds; a warm load
            // executed no walks.
            if ss.walk_steps == 0 { "-".to_string() } else { fmt_count(ss.walk_steps) },
            if ss.walk_steps == 0 {
                "-".to_string()
            } else {
                format!("{:.1}x", ss.naive_steps as f64 / ss.walk_steps as f64)
            },
        ]);
        println!("{} {}", if hit { "already cached:" } else { "wrote" }, out.display());
    }
    t.print();
    println!(
        "warm-start it with: syncode serve --grammars {} --cache-dir {}",
        gnames.join(","),
        cache_dir
    );
}

fn cmd_generate(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let (tok, docs, use_mock) = serving_tokenizer(args, std::slice::from_ref(&gname));
    let model = model_factory(args, use_mock, tok.clone(), docs);
    let art = artifact_for(args, &gname, tok.clone());
    let srv = Server::start(model, tok.clone(), art.engine_factory());
    let prompt = args.get_or("prompt", "Please generate a JSON object.");
    let req = GenRequest {
        id: 1,
        prompt,
        constraint_prefix: args.get_or("prefix", ""),
        grammar: None,
        params: params_from(args),
        token_sink: None,
    };
    let resp = if args.flag("stream") {
        // Token-by-token: each committed token prints the moment the
        // scheduler commits it (the same event stream `serve --http`
        // exposes as SSE).
        use std::io::Write as _;
        let resp = srv.submit_stream(req).for_each_text(|text| {
            print!("{text}");
            let _ = std::io::stdout().flush();
        });
        println!();
        resp
    } else {
        let resp = srv.generate(req);
        println!("{}", resp.text);
        resp
    };
    println!(
        "--- generation ({:?}, {} tokens, ttft {:.3}s, total {:.2}s) ---",
        resp.finish, resp.tokens, resp.ttft_secs, resp.latency_secs
    );
    if let Some(e) = resp.error {
        eprintln!("error: {e}");
    }
    srv.shutdown();
}

fn cmd_serve(args: &Args) {
    let gnames = grammars_arg(args, "serve");
    let n = args.get_num("requests", 8usize);
    let (tok, union_docs, use_mock) = serving_tokenizer(args, &gnames);

    // Registry: one compiled artifact per grammar, same tokenizer.
    let registry = Arc::new(GrammarRegistry::new());
    for g in &gnames {
        let art = artifact_for(args, g, tok.clone());
        registry.register(art).unwrap_or_else(|e| panic!("register {g}: {e}"));
    }
    eprintln!("[registry: {}]", registry.names().join(", "));

    let replicas = args.get_num("replicas", 1usize).max(1);
    let defaults = CoordinatorConfig::default();
    let cfg = CoordinatorConfig {
        mask_threads: args.get_num("mask-threads", 0usize),
        queue_cap: args.get_num("queue-cap", 256usize),
        spec_k_cap: args.get_num("spec-k-cap", defaults.spec_k_cap),
        batch_queue_cap: args.get("batch-queue-cap").map(|v| {
            v.parse().unwrap_or_else(|_| {
                eprintln!("--batch-queue-cap must be a number, got '{v}'");
                std::process::exit(2);
            })
        }),
        batch_age_ms: args.get_num("batch-age-ms", defaults.batch_age_ms),
        compile_limits: compile_limits_from(args),
    };
    eprintln!(
        "[coordinator: {} replica(s), {} mask thread(s), queue cap {} (batch {}), \
         spec_k cap {}, batch age {}ms]",
        replicas,
        cfg.mask_threads,
        cfg.queue_cap,
        cfg.batch_queue_cap.unwrap_or(cfg.queue_cap),
        cfg.spec_k_cap,
        cfg.batch_age_ms
    );
    let limits = cfg.compile_limits;
    let factories = model_factories(args, use_mock, &tok, &union_docs, replicas);
    let srv = Coordinator::start(factories, tok, registry.clone(), cfg);

    // Hot-reload: poll a directory of *.lark files into the registry.
    // Broken edits keep the previous version serving; see
    // `artifact/watch.rs`.
    let watch_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let watch_thread = args.get("watch").map(|dir| {
        let watch_ms = args.get_num("watch-ms", 500u64);
        eprintln!("[watch: polling {dir} every {watch_ms}ms]");
        GrammarWatcher::new(
            PathBuf::from(&dir),
            registry.clone(),
            artifact_cfg(args),
            limits,
            args.get("cache-dir").map(PathBuf::from),
        )
        .spawn(watch_ms, watch_stop.clone())
    });
    let stop_watch = |t: Option<std::thread::JoinHandle<()>>| {
        watch_stop.store(true, std::sync::atomic::Ordering::Release);
        if let Some(t) = t {
            let _ = t.join();
        }
    };

    // Network mode: adapt the coordinator onto HTTP and run until a
    // graceful shutdown (`POST /admin/shutdown`) drains it.
    if let Some(addr) = args.get("http") {
        let http_defaults = HttpConfig::default();
        let http_cfg = HttpConfig {
            workers: args.get_num("http-workers", 8usize),
            sse_keepalive_ms: args
                .get_num("sse-keepalive-ms", http_defaults.sse_keepalive_ms),
            grammar_api: GrammarApiConfig {
                limits,
                artifact: artifact_cfg(args),
                cache_dir: args.get("cache-dir").map(PathBuf::from),
            },
        };
        let server = HttpServer::bind(addr, srv, registry.clone(), http_cfg)
            .unwrap_or_else(|e| panic!("http bind {addr}: {e}"));
        // Machine-readable (ci.sh greps it); `--http 127.0.0.1:0` picks an
        // ephemeral port, surfaced only here.
        println!("[http] listening on {}", server.local_addr());
        println!(
            "[http] POST /v1/generate (?stream=1 for SSE) | POST/DELETE /v1/grammars | GET /v1/grammars /healthz /metrics | POST /admin/shutdown"
        );
        let handle = server.wait();
        stop_watch(watch_thread);
        println!("[http] drained; final metrics:");
        println!("global: {}", handle.snapshot().report());
        let rs = registry.stats();
        println!(
            "grammars: {} registered, {} compiles ({} cache hits), {} errors, {} evictions",
            rs.registered, rs.compiles, rs.cache_hits, rs.compile_errors, rs.evictions
        );
        handle.shutdown();
        return;
    }

    let params = params_from(args);
    // Round-robin the registered grammars across the request stream: the
    // scheduler batches them into the same decode loop.
    let json_tasks = dataset::json_mode_tasks(n, 3);
    let reqs: Vec<GenRequest> = (0..n as u64)
        .map(|i| {
            let g = gnames[i as usize % gnames.len()].clone();
            let prompt = match g.as_str() {
                "json" => json_tasks[i as usize].prompt.clone(),
                _ => format!("produce a valid {g} snippet (#{i})"),
            };
            GenRequest {
                id: i,
                prompt,
                constraint_prefix: String::new(),
                grammar: Some(g),
                params: params.clone(),
                token_sink: None,
            }
        })
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|r| srv.submit(r.clone())).collect();
    let mut syntax_errors = 0usize;
    for (req, rx) in reqs.iter().zip(rxs) {
        let r = rx
            .recv()
            .unwrap_or_else(|_| syncode::coordinator::GenResponse::rejected(req.id, "no response"));
        let g = req.grammar.as_deref().unwrap_or("?");
        let valid = registry.get(g).map(|art| art.response_valid(&r)).unwrap_or(false);
        syntax_errors += !valid as usize;
        println!(
            "req {:2} [{:8}] {:?} {:3} tokens valid={} | {}",
            req.id,
            g,
            r.finish,
            r.tokens,
            valid,
            r.text.lines().next().unwrap_or("")
        );
    }
    println!("\nsyntax errors: {syntax_errors}/{n}");
    println!();
    if replicas > 1 {
        for (i, snap) in srv.replica_snapshots().iter().enumerate() {
            println!("replica {i}: {}", snap.report());
        }
    }
    println!("global: {}", srv.snapshot().report());
    stop_watch(watch_thread);
    srv.shutdown();
}

fn cmd_grammar(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let cx = GrammarContext::builtin(&gname, LrMode::Lalr).expect("grammar");
    let g = &cx.grammar;
    println!(
        "grammar {gname}: {} rules, {} terminals, {} nonterminals",
        g.rules.len(),
        g.terminals.len(),
        g.nonterminals.len()
    );
    println!("|Q_Ω| = {} DFA states", g.total_dfa_states());
    for mode in [LrMode::Lalr, LrMode::Canonical] {
        if gname == "python" && mode == LrMode::Canonical && !args.flag("canonical") {
            println!("(skipping canonical LR(1) for python; pass --canonical)");
            continue;
        }
        let t = LrTable::build(g, mode);
        println!(
            "{mode:?}: {} states, {} KB tables, {} conflicts",
            t.num_states,
            t.size_bytes() / 1024,
            t.conflicts.len()
        );
        if args.flag("report") {
            for c in t.conflicts.iter().take(20) {
                println!("  {c}");
            }
        }
    }
}

fn cmd_maskstore(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let merges = args.get_num("merges", 300usize);
    let env = EvalEnv::new(&gname, 120, merges, 7);
    let s = &env.store.stats;
    let mut t = Table::new(&[
        "grammar", "|V|", "|Q|", "|Γ|", "threads", "build(s)", "masks", "mem", "raw",
        "steps", "naive", "÷", "pruned",
    ]);
    t.row(&[
        gname.clone(),
        s.vocab_size.to_string(),
        s.num_dfa_states.to_string(),
        s.num_terminals.to_string(),
        s.build_threads.to_string(),
        format!("{:.2}", s.build_secs),
        s.unique_masks.to_string(),
        format!("{:.1}MB", s.mem_bytes as f64 / 1e6),
        format!("{:.1}MB", s.raw_bytes as f64 / 1e6),
        fmt_count(s.walk_steps),
        fmt_count(s.naive_steps),
        format!("{:.1}x", s.naive_steps as f64 / s.walk_steps.max(1) as f64),
        fmt_count(s.pruned_dead_byte),
    ]);
    t.print();
}

fn cmd_experiment(args: &Args) {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table1");
    let params = params_from(args);
    match which {
        "table1" => {
            let env = EvalEnv::new("json", 120, 160, 11);
            let tasks = dataset::json_mode_tasks(args.get_num("tasks", 10), 3);
            let mut t =
                Table::new(&["engine", "syntax errs", "schema valid", "time(s)", "tokens"]);
            for kind in EngineKind::ALL {
                let r = harness::run_json(&env, &tasks, kind, false, &params);
                t.row(&[
                    r.engine.to_string(),
                    r.syntax_errors.to_string(),
                    format!("{}/{}", r.schema_valid, r.total),
                    format!("{:.3}", r.avg_time_s),
                    format!("{:.1}", r.avg_tokens),
                ]);
            }
            t.print();
        }
        "table2" => {
            let env = EvalEnv::new("sql", 120, 160, 13);
            let tasks = dataset::spider_tasks(args.get_num("tasks", 3), 5);
            let mut t = Table::new(&[
                "engine", "easy", "med", "hard", "extra", "overall", "exec%", "tokens",
                "time(s)",
            ]);
            for kind in [EngineKind::Standard, EngineKind::Syncode] {
                let r = harness::run_sql(&env, &tasks, kind, &params);
                let pct =
                    |d| format!("{:.0}%", r.accuracy.get(&d).copied().unwrap_or(0.0) * 100.0);
                t.row(&[
                    r.engine.to_string(),
                    pct(dataset::Difficulty::Easy),
                    pct(dataset::Difficulty::Medium),
                    pct(dataset::Difficulty::Hard),
                    pct(dataset::Difficulty::Extra),
                    format!("{:.0}%", r.overall_accuracy * 100.0),
                    format!("{:.0}%", r.execute_pct * 100.0),
                    format!("{:.1}", r.avg_tokens),
                    format!("{:.3}", r.avg_time_s),
                ]);
            }
            t.print();
        }
        "table3" => {
            let mut t = Table::new(&["lang", "engine", "errors/total", "time(s)"]);
            for lang in ["python", "go"] {
                let env = EvalEnv::new(lang, 80, 120, 17);
                let tasks = match lang {
                    "python" => dataset::python_tasks(args.get_num("tasks", 5), 3),
                    _ => dataset::go_tasks(args.get_num("tasks", 5), 3),
                };
                for kind in [EngineKind::Standard, EngineKind::Syncode] {
                    let r = harness::run_gpl(&env, &tasks, kind, 2, &params);
                    t.row(&[
                        lang.to_string(),
                        r.engine.to_string(),
                        format!("{}/{}", r.syntax_errors, r.total),
                        format!("{:.3}", r.avg_time_s),
                    ]);
                }
            }
            t.print();
        }
        "table4" => {
            let env = EvalEnv::new("calc", 120, 80, 19);
            let tasks = dataset::calc_tasks(args.get_num("tasks", 6), 7);
            let mut t = Table::new(&["engine", "pass@1", "pass@10"]);
            for kind in [EngineKind::Standard, EngineKind::Syncode] {
                let r = harness::run_calc_passk(&env, &tasks, kind, 10, &params);
                t.row(&[
                    r.engine.to_string(),
                    format!("{:.3}", r.pass_at_1),
                    format!("{:.3}", r.pass_at_10),
                ]);
            }
            t.print();
        }
        other => {
            eprintln!("unknown experiment {other} (table1|table2|table3|table4)");
            std::process::exit(2);
        }
    }
}

fn cmd_check(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let path = args.positional.first().expect("usage: syncode check <file> --grammar g");
    let cx = GrammarContext::builtin(&gname, LrMode::Lalr).expect("grammar");
    let text = std::fs::read(path).expect("read file");
    match cx.check_complete(&text) {
        Ok(()) => println!("OK: valid {gname}"),
        Err(e) => {
            println!("SYNTAX ERROR: {e}");
            std::process::exit(1);
        }
    }
}
