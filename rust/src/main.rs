//! `syncode` CLI — leader entrypoint for the serving stack.
//!
//! Subcommands:
//!
//! - `generate`   one-shot constrained generation (mock or PJRT model);
//! - `serve`      run the batch server over a synthetic request stream;
//! - `grammar`    inspect a built-in grammar (terminals, LR tables, conflicts);
//! - `maskstore`  build a DFA mask store and print its statistics (Table 5);
//! - `experiment` run a paper experiment (table1|table2|table3|table4);
//! - `check`      syntax-check a file against a grammar (the oracle).

use std::sync::Arc;
use syncode::coordinator::{GenParams, GenRequest, Server, Strategy};
use syncode::engine::GrammarContext;
use syncode::eval::dataset;
use syncode::eval::harness::{self, EngineKind, EvalEnv};
use syncode::mask::{MaskStore, MaskStoreConfig};
use syncode::parser::{LrMode, LrTable};
use syncode::runtime::{ModelFactory, PjrtModel, PjrtVariant};
use syncode::tokenizer::Tokenizer;
use syncode::util::bench::Table;
use syncode::util::cli::Args;

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("grammar") => cmd_grammar(&args),
        Some("maskstore") => cmd_maskstore(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("check") => cmd_check(&args),
        _ => {
            eprintln!(
                "usage: syncode <generate|serve|grammar|maskstore|experiment|check> [--opts]\n\
                 common: --grammar <json|calc|sql|python|go> --artifacts <dir> --mock"
            );
            std::process::exit(2);
        }
    }
}

fn params_from(args: &Args) -> GenParams {
    let temp = args.get_num("temperature", 0.7f32);
    let strategy = match args.get_or("strategy", "topp").as_str() {
        "greedy" => Strategy::Greedy,
        "temp" => Strategy::Temperature(temp),
        _ => Strategy::TopP { temp, p: args.get_num("top-p", 0.95f32) },
    };
    GenParams {
        max_new_tokens: args.get_num("max-tokens", 120),
        strategy,
        seed: args.get_num("seed", 7u64),
        opportunistic: !args.flag("no-opportunistic"),
    }
}

/// Model + tokenizer from artifacts (PJRT) or the mock fallback.
fn model_and_tok(args: &Args, env: &EvalEnv) -> (ModelFactory, Arc<Tokenizer>) {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let use_mock = args.flag("mock") || !dir.join("config.json").exists();
    if use_mock {
        eprintln!("[model: mock-bigram — pass --artifacts or run `make artifacts` for PJRT]");
        (env.model_factory(), env.tok.clone())
    } else {
        let tok = Arc::new(
            Tokenizer::from_file(&dir.join("tokenizer.json")).expect("tokenizer.json"),
        );
        let variant = if args.flag("full-recompute") {
            PjrtVariant::FullRecompute
        } else {
            PjrtVariant::KvCache
        };
        let f: ModelFactory = Box::new(move || Ok(Box::new(PjrtModel::load(&dir, variant)?)));
        (f, tok)
    }
}

fn syncode_factory(
    env: &EvalEnv,
    tok: &Arc<Tokenizer>,
) -> syncode::coordinator::EngineFactory {
    // The store must match the *serving* tokenizer (which differs from the
    // env's mock tokenizer when artifacts are loaded).
    let store = Arc::new(MaskStore::build(&env.cx.grammar, tok, MaskStoreConfig::default()));
    let cx = env.cx.clone();
    let tok = tok.clone();
    Box::new(move || {
        Box::new(syncode::engine::SyncodeEngine::new(cx.clone(), store.clone(), tok.clone()))
    })
}

fn cmd_generate(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let env = EvalEnv::new(&gname, 80, 120, args.get_num("seed", 7));
    let (model, tok) = model_and_tok(args, &env);
    let srv = Server::start(model, tok.clone(), syncode_factory(&env, &tok));
    let prompt = args.get_or("prompt", "Please generate a JSON object.");
    let resp = srv.generate(GenRequest {
        id: 1,
        prompt,
        constraint_prefix: args.get_or("prefix", ""),
        params: params_from(args),
    });
    println!(
        "--- generation ({:?}, {} tokens, {:.2}s) ---",
        resp.finish, resp.tokens, resp.latency_secs
    );
    println!("{}", resp.text);
    if let Some(e) = resp.error {
        eprintln!("error: {e}");
    }
    srv.shutdown();
}

fn cmd_serve(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let n = args.get_num("requests", 8usize);
    let env = EvalEnv::new(&gname, 80, 120, args.get_num("seed", 7));
    let (model, tok) = model_and_tok(args, &env);
    let srv = Server::start(model, tok.clone(), syncode_factory(&env, &tok));
    let tasks = dataset::json_mode_tasks(n, 3);
    let params = params_from(args);
    let rxs: Vec<_> = tasks
        .iter()
        .map(|t| {
            srv.submit(GenRequest {
                id: t.id,
                prompt: t.prompt.clone(),
                constraint_prefix: String::new(),
                params: params.clone(),
            })
        })
        .collect();
    for (t, rx) in tasks.iter().zip(rxs) {
        let r = rx.recv().unwrap();
        println!("req {}: {:?} {} tokens | {}", t.id, r.finish, r.tokens, r.text);
    }
    println!("\n{}", srv.metrics.lock().unwrap().snapshot().report());
    srv.shutdown();
}

fn cmd_grammar(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let cx = GrammarContext::builtin(&gname, LrMode::Lalr).expect("grammar");
    let g = &cx.grammar;
    println!(
        "grammar {gname}: {} rules, {} terminals, {} nonterminals",
        g.rules.len(),
        g.terminals.len(),
        g.nonterminals.len()
    );
    println!("|Q_Ω| = {} DFA states", g.total_dfa_states());
    for mode in [LrMode::Lalr, LrMode::Canonical] {
        if gname == "python" && mode == LrMode::Canonical && !args.flag("canonical") {
            println!("(skipping canonical LR(1) for python; pass --canonical)");
            continue;
        }
        let t = LrTable::build(g, mode);
        println!(
            "{mode:?}: {} states, {} KB tables, {} conflicts",
            t.num_states,
            t.size_bytes() / 1024,
            t.conflicts.len()
        );
        if args.flag("report") {
            for c in t.conflicts.iter().take(20) {
                println!("  {c}");
            }
        }
    }
}

fn cmd_maskstore(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let merges = args.get_num("merges", 300usize);
    let env = EvalEnv::new(&gname, 120, merges, 7);
    let s = &env.store.stats;
    let mut t =
        Table::new(&["grammar", "|V|", "|Q|", "|Γ|", "build(s)", "masks", "mem", "raw"]);
    t.row(&[
        gname.clone(),
        s.vocab_size.to_string(),
        s.num_dfa_states.to_string(),
        s.num_terminals.to_string(),
        format!("{:.2}", s.build_secs),
        s.unique_masks.to_string(),
        format!("{:.1}MB", s.mem_bytes as f64 / 1e6),
        format!("{:.1}MB", s.raw_bytes as f64 / 1e6),
    ]);
    t.print();
}

fn cmd_experiment(args: &Args) {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("table1");
    let params = params_from(args);
    match which {
        "table1" => {
            let env = EvalEnv::new("json", 120, 160, 11);
            let tasks = dataset::json_mode_tasks(args.get_num("tasks", 10), 3);
            let mut t =
                Table::new(&["engine", "syntax errs", "schema valid", "time(s)", "tokens"]);
            for kind in EngineKind::ALL {
                let r = harness::run_json(&env, &tasks, kind, false, &params);
                t.row(&[
                    r.engine.to_string(),
                    r.syntax_errors.to_string(),
                    format!("{}/{}", r.schema_valid, r.total),
                    format!("{:.3}", r.avg_time_s),
                    format!("{:.1}", r.avg_tokens),
                ]);
            }
            t.print();
        }
        "table2" => {
            let env = EvalEnv::new("sql", 120, 160, 13);
            let tasks = dataset::spider_tasks(args.get_num("tasks", 3), 5);
            let mut t = Table::new(&[
                "engine", "easy", "med", "hard", "extra", "overall", "exec%", "tokens",
                "time(s)",
            ]);
            for kind in [EngineKind::Standard, EngineKind::Syncode] {
                let r = harness::run_sql(&env, &tasks, kind, &params);
                let pct =
                    |d| format!("{:.0}%", r.accuracy.get(&d).copied().unwrap_or(0.0) * 100.0);
                t.row(&[
                    r.engine.to_string(),
                    pct(dataset::Difficulty::Easy),
                    pct(dataset::Difficulty::Medium),
                    pct(dataset::Difficulty::Hard),
                    pct(dataset::Difficulty::Extra),
                    format!("{:.0}%", r.overall_accuracy * 100.0),
                    format!("{:.0}%", r.execute_pct * 100.0),
                    format!("{:.1}", r.avg_tokens),
                    format!("{:.3}", r.avg_time_s),
                ]);
            }
            t.print();
        }
        "table3" => {
            let mut t = Table::new(&["lang", "engine", "errors/total", "time(s)"]);
            for lang in ["python", "go"] {
                let env = EvalEnv::new(lang, 80, 120, 17);
                let tasks = match lang {
                    "python" => dataset::python_tasks(args.get_num("tasks", 5), 3),
                    _ => dataset::go_tasks(args.get_num("tasks", 5), 3),
                };
                for kind in [EngineKind::Standard, EngineKind::Syncode] {
                    let r = harness::run_gpl(&env, &tasks, kind, 2, &params);
                    t.row(&[
                        lang.to_string(),
                        r.engine.to_string(),
                        format!("{}/{}", r.syntax_errors, r.total),
                        format!("{:.3}", r.avg_time_s),
                    ]);
                }
            }
            t.print();
        }
        "table4" => {
            let env = EvalEnv::new("calc", 120, 80, 19);
            let tasks = dataset::calc_tasks(args.get_num("tasks", 6), 7);
            let mut t = Table::new(&["engine", "pass@1", "pass@10"]);
            for kind in [EngineKind::Standard, EngineKind::Syncode] {
                let r = harness::run_calc_passk(&env, &tasks, kind, 10, &params);
                t.row(&[
                    r.engine.to_string(),
                    format!("{:.3}", r.pass_at_1),
                    format!("{:.3}", r.pass_at_10),
                ]);
            }
            t.print();
        }
        other => {
            eprintln!("unknown experiment {other} (table1|table2|table3|table4)");
            std::process::exit(2);
        }
    }
}

fn cmd_check(args: &Args) {
    let gname = args.get_or("grammar", "json");
    let path = args.positional.first().expect("usage: syncode check <file> --grammar g");
    let cx = GrammarContext::builtin(&gname, LrMode::Lalr).expect("grammar");
    let text = std::fs::read(path).expect("read file");
    match cx.check_complete(&text) {
        Ok(()) => println!("OK: valid {gname}"),
        Err(e) => {
            println!("SYNTAX ERROR: {e}");
            std::process::exit(1);
        }
    }
}
