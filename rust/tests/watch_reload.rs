//! Determinism tests for `serve --watch` hot-reload: an in-flight
//! generation pinned across a reload finishes byte-identical to a
//! no-watch baseline, new submissions pick up the reloaded grammar, and
//! a broken edit keeps the old grammar serving while tallying
//! `compile_errors`.
//!
//! The tests drive [`GrammarWatcher::scan_once`] synchronously — the
//! same unit the polling thread loops over — so every interleaving
//! (reload strictly between "request admitted" and "request finished")
//! is exact, not timing-dependent. The model is a gate-stalled
//! uniform-logits stub: decoding blocks inside the model until the test
//! releases it, and with greedy sampling the output is a pure function
//! of the grammar the request holds.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;
use syncode::artifact::{
    ArtifactConfig, CompiledGrammar, GrammarRegistry, GrammarWatcher,
};
use syncode::coordinator::{
    Coordinator, CoordinatorConfig, GenParams, GenRequest, GenResponse, ServerHandle, Strategy,
};
use syncode::grammar::CompileLimits;
use syncode::runtime::{replicate_factory, LanguageModel};
use syncode::tokenizer::Tokenizer;

const SRC_AB: &str = "start: A+\nA: /[ab]/\n";
// Different length than SRC_AB on purpose: the watcher stamps
// `(mtime, len)`, and a same-second rewrite on a coarse-mtime
// filesystem is only caught when the length moves.
const SRC_CD: &str = "// v2\nstart: B+\nB: /[cd]/\n";
const SRC_BROKEN: &str = "start: %%% broken beyond repair\n";

struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Uniform-logits model: first decode signals `entered`, then blocks on
/// the gate. The grammar mask does all the shaping.
struct StallModel {
    vocab: usize,
    gate: Arc<Gate>,
    entered: Option<Sender<()>>,
}

impl LanguageModel for StallModel {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn lanes(&self) -> usize {
        2
    }

    fn max_seq(&self) -> usize {
        256
    }

    fn prefill(&mut self, _lane: usize, _tokens: &[u32]) -> syncode::util::error::Result<Vec<f32>> {
        Ok(vec![0.0; self.vocab])
    }

    fn decode(
        &mut self,
        last: &[Option<u32>],
    ) -> syncode::util::error::Result<Vec<Option<Vec<f32>>>> {
        if let Some(tx) = self.entered.take() {
            let _ = tx.send(());
        }
        self.gate.wait();
        Ok(last.iter().map(|t| t.map(|_| vec![0.0; self.vocab])).collect())
    }

    fn release(&mut self, _lane: usize) {}

    fn name(&self) -> &'static str {
        "stall"
    }
}

struct Harness {
    dir: std::path::PathBuf,
    file: std::path::PathBuf,
    reg: Arc<GrammarRegistry>,
    watcher: GrammarWatcher,
    srv: ServerHandle,
    gate: Arc<Gate>,
    entered: Receiver<()>,
}

fn harness(tag: &str) -> Harness {
    let dir = std::env::temp_dir().join(format!("syncode_watch_reload_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("wdsl.lark");
    std::fs::write(&file, SRC_AB).unwrap();

    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = Arc::new(GrammarRegistry::new());
    let cfg = ArtifactConfig::default();
    reg.register(CompiledGrammar::compile("calc", tok.clone(), &cfg).unwrap()).unwrap();

    let mut watcher =
        GrammarWatcher::new(dir.clone(), reg.clone(), cfg, CompileLimits::default(), None);
    let first = watcher.scan_once();
    assert_eq!(first.reloaded, vec!["wdsl".to_string()], "{first:?}");
    assert!(first.errors.is_empty(), "{first:?}");

    let gate = Gate::new();
    let (etx, entered) = channel();
    let vocab = tok.vocab_size();
    let gate_m = gate.clone();
    let etx = Arc::new(Mutex::new(Some(etx)));
    let factories = replicate_factory(1, move || {
        Ok(Box::new(StallModel {
            vocab,
            gate: gate_m.clone(),
            entered: etx.lock().unwrap().take(),
        }) as Box<dyn LanguageModel>)
    });
    let srv = Coordinator::start(
        factories,
        tok,
        reg.clone(),
        CoordinatorConfig { mask_threads: 0, queue_cap: 16, ..Default::default() },
    );
    Harness { dir, file, reg, watcher, srv, gate, entered }
}

fn request(id: u64, max_new_tokens: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: format!("produce wdsl #{id}"),
        constraint_prefix: String::new(),
        grammar: Some("wdsl".to_string()),
        params: GenParams {
            max_new_tokens,
            strategy: Strategy::Greedy,
            seed: 17,
            ..Default::default()
        },
        token_sink: None,
    }
}

fn recv(rx: std::sync::mpsc::Receiver<GenResponse>) -> GenResponse {
    let resp = rx.recv_timeout(Duration::from_secs(60)).expect("generation finished");
    assert!(resp.error.is_none(), "{:?}", resp.error);
    resp
}

/// Pin one generation inside the model, optionally reload mid-flight,
/// release, and return the finished text.
fn pinned_generation(tag: &str, reload_mid_flight: bool) -> String {
    let mut h = harness(tag);
    let art_old = h.reg.get("wdsl").unwrap();

    let rx = h.srv.submit(request(1, 4));
    h.entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");

    if reload_mid_flight {
        // Replace the watched file with a grammar that would reject the
        // in-flight output; the reload must not touch the pinned Arc.
        std::fs::write(&h.file, SRC_CD).unwrap();
        let r = h.watcher.scan_once();
        assert_eq!(r.reloaded, vec!["wdsl".to_string()], "{r:?}");
        let art_new = h.reg.get("wdsl").unwrap();
        assert!(!Arc::ptr_eq(&art_old, &art_new), "reload must swap the registry entry");
        assert_eq!(h.reg.stats().evictions, 0, "replace-in-place never evicts");
    }

    h.gate.release();
    let resp = recv(rx);
    assert!(!resp.text.is_empty());
    assert!(
        resp.text.bytes().all(|b| b == b'a' || b == b'b'),
        "in-flight output leaked the reloaded grammar: {:?}",
        resp.text
    );
    assert!(art_old.response_valid(&resp), "{:?}", resp.text);

    if reload_mid_flight {
        // A submission made AFTER the reload generates under the new
        // grammar: c/d bytes only.
        let resp2 = recv(h.srv.submit(request(2, 4)));
        assert!(!resp2.text.is_empty());
        assert!(
            resp2.text.bytes().all(|b| b == b'c' || b == b'd'),
            "new submission did not pick up the reload: {:?}",
            resp2.text
        );
        assert!(h.reg.get("wdsl").unwrap().response_valid(&resp2));
    }

    h.srv.shutdown();
    let _ = std::fs::remove_dir_all(&h.dir);
    resp.text
}

#[test]
fn inflight_generation_is_byte_identical_across_a_reload() {
    let baseline = pinned_generation("baseline", false);
    let reloaded = pinned_generation("reload", true);
    assert_eq!(
        baseline, reloaded,
        "a mid-flight hot-reload must not perturb pinned generations"
    );
}

#[test]
fn broken_edit_keeps_old_grammar_serving_and_counts_the_error() {
    let mut h = harness("broken");
    h.gate.release(); // free-flowing model for this test
    let art_v1 = h.reg.get("wdsl").unwrap();
    let errors_before = h.reg.stats().compile_errors;

    // A broken edit: reported, tallied, old grammar untouched.
    std::fs::write(&h.file, SRC_BROKEN).unwrap();
    let r = h.watcher.scan_once();
    assert!(r.reloaded.is_empty(), "{r:?}");
    assert_eq!(r.errors.len(), 1, "{r:?}");
    assert_eq!(r.errors[0].0, "wdsl");
    assert_eq!(h.reg.stats().compile_errors, errors_before + 1);
    assert!(Arc::ptr_eq(&h.reg.get("wdsl").unwrap(), &art_v1), "old grammar evicted");

    // The grammar still serves generations.
    let resp = recv(h.srv.submit(request(3, 4)));
    assert!(resp.text.bytes().all(|b| b == b'a' || b == b'b'), "{:?}", resp.text);
    assert!(art_v1.response_valid(&resp));

    // The broken file is not re-attempted while unchanged...
    let r = h.watcher.scan_once();
    assert!(r.errors.is_empty() && r.reloaded.is_empty(), "{r:?}");
    assert_eq!(h.reg.stats().compile_errors, errors_before + 1);

    // ...and a fixing edit recovers without a restart.
    std::fs::write(&h.file, SRC_CD).unwrap();
    let r = h.watcher.scan_once();
    assert_eq!(r.reloaded, vec!["wdsl".to_string()], "{r:?}");
    let resp = recv(h.srv.submit(request(4, 4)));
    assert!(resp.text.bytes().all(|b| b == b'c' || b == b'd'), "{:?}", resp.text);

    h.srv.shutdown();
    let _ = std::fs::remove_dir_all(&h.dir);
}
