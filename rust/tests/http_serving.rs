//! End-to-end tests for the HTTP serving front: concurrent raw-socket
//! clients getting grammar-valid output, malformed-request handling,
//! backpressure surfacing as 429, dead/draining coordinators as 503, and
//! graceful shutdown that completes in-flight generations.
//!
//! Everything runs over real TCP sockets on ephemeral loopback ports via
//! the crate's own minimal client (`net::http::fetch`) or hand-written
//! request bytes — the same path an external curl would take.

use std::io::Write as _;
use std::net::{Shutdown, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};
use syncode::artifact::{ArtifactConfig, CompiledGrammar, GrammarRegistry};
use syncode::coordinator::{Coordinator, CoordinatorConfig, GenResponse};
use syncode::net::http::{fetch, read_response, HttpClient};
use syncode::net::json::finish_from_str;
use syncode::net::{HttpConfig, HttpServer};
use syncode::runtime::{replicate_factory, LanguageModel, MockModel, ModelFactory};
use syncode::tokenizer::Tokenizer;
use syncode::util::json::{parse, Json};

/// Mixed corpus so the mock bigram model emits plausible bytes for both
/// registered grammars.
fn docs() -> Vec<Vec<u8>> {
    vec![
        br#"{"name": "alice", "age": 30}"#.to_vec(),
        br#"{"items": [1, 2, 3], "ok": true}"#.to_vec(),
        br#"{"nested": {"a": null}}"#.to_vec(),
        b"1 + 2 * 3".to_vec(),
        b"math_sqrt(4) - 1".to_vec(),
        b"(7 - 2) / 5".to_vec(),
    ]
}

fn registry(tok: &Arc<Tokenizer>) -> Arc<GrammarRegistry> {
    let reg = Arc::new(GrammarRegistry::new());
    for g in ["json", "calc"] {
        let art = CompiledGrammar::compile(g, tok.clone(), &ArtifactConfig::default()).unwrap();
        reg.register(art).unwrap();
    }
    reg
}

/// Start a full coordinator + HTTP front on an ephemeral port over the
/// mock model. Returns the server, the registry (for re-validation) and
/// the dial address.
fn start_mock_http(
    replicas: usize,
    lanes: usize,
    queue_cap: usize,
) -> (HttpServer, Arc<GrammarRegistry>, String) {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let tok_m = tok.clone();
    let factories = replicate_factory(replicas, move || {
        Ok(Box::new(MockModel::from_documents(tok_m.clone(), &docs(), lanes, 256, 11))
            as Box<dyn LanguageModel>)
    });
    let cfg = CoordinatorConfig { mask_threads: 0, queue_cap, ..Default::default() };
    let handle = Coordinator::start(factories, tok, reg.clone(), cfg);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        handle,
        reg.clone(),
        HttpConfig { workers: 6, ..Default::default() },
    )
    .expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (server, reg, addr)
}

fn generate_body(grammar: &str, seed: u64, max_tokens: usize) -> String {
    format!(
        r#"{{"grammar": "{grammar}", "prompt": "produce {grammar} #{seed}",
           "max_tokens": {max_tokens}, "seed": {seed}}}"#
    )
}

/// Send raw bytes, half-close the write side, parse whatever comes back.
fn raw_roundtrip(addr: &str, bytes: &[u8]) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(bytes).expect("write");
    s.shutdown(Shutdown::Write).expect("half-close");
    read_response(&mut s).expect("response")
}

fn poll_until(deadline_secs: u64, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(deadline_secs);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn healthz_queue_depth(addr: &str) -> usize {
    let (_, body) = fetch(addr, "GET", "/healthz", None).expect("healthz");
    parse(&body)
        .ok()
        .and_then(|v| v.get("queue_depth").and_then(Json::as_usize))
        .unwrap_or(usize::MAX)
}

#[test]
fn concurrent_clients_get_grammar_valid_output() {
    let (server, reg, addr) = start_mock_http(2, 2, 64);
    let n = 8;
    let results: Vec<(u16, String, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let g = if i % 2 == 0 { "json" } else { "calc" };
                let addr = addr.clone();
                s.spawn(move || {
                    let (status, body) = fetch(
                        addr.as_str(),
                        "POST",
                        "/v1/generate",
                        Some(&generate_body(g, i, 48)),
                    )
                    .expect("request");
                    (status, body, g.to_string())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    for (status, body, grammar) in results {
        assert_eq!(status, 200, "body: {body}");
        let v = parse(&body).expect("response json");
        assert_eq!(v.get("grammar").unwrap().as_str(), Some(grammar.as_str()));
        assert_eq!(v.get("valid").unwrap().as_bool(), Some(true), "{body}");
        assert!(v.get("error").is_none(), "{body}");
        // Don't take the server's word for it: rebuild the response and
        // re-run the shared validity oracle client-side.
        let resp = GenResponse {
            id: v.get("id").unwrap().as_usize().unwrap() as u64,
            text: v.get("text").unwrap().as_str().unwrap().to_string(),
            finish: finish_from_str(v.get("finish").unwrap().as_str().unwrap()).unwrap(),
            tokens: v.get("tokens").unwrap().as_usize().unwrap(),
            ttft_secs: 0.0,
            latency_secs: 0.0,
            error: None,
        };
        assert!(
            reg.get(&grammar).unwrap().response_valid(&resp),
            "server said valid but the oracle disagrees: {body}"
        );
    }
    server.shutdown().shutdown();
}

#[test]
fn registry_health_and_metrics_endpoints() {
    let (server, _reg, addr) = start_mock_http(1, 2, 64);

    let (status, body) = fetch(addr.as_str(), "GET", "/v1/grammars", None).unwrap();
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    assert_eq!(v.get("default").unwrap().as_str(), Some("json"));
    let names: Vec<&str> = v
        .get("grammars")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|g| g.get("name").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(names, ["calc", "json"]);
    for g in v.get("grammars").unwrap().as_arr().unwrap() {
        assert!(g.get("vocab_size").unwrap().as_usize().unwrap() > 0);
        assert!(g.get("dfa_states").unwrap().as_usize().unwrap() > 0);
    }

    let (status, body) = fetch(addr.as_str(), "GET", "/healthz", None).unwrap();
    assert_eq!(status, 200);
    let health = parse(&body).unwrap();
    assert_eq!(health.get("status").unwrap().as_str(), Some("ok"));
    // Supervision state is part of the health report.
    assert_eq!(health.get("replicas_live").and_then(Json::as_usize), Some(1), "{body}");
    assert_eq!(health.get("replicas_total").and_then(Json::as_usize), Some(1), "{body}");

    // Default grammar (no "grammar" field) routes to the registry default.
    let (status, body) = fetch(
        addr.as_str(),
        "POST",
        "/v1/generate",
        Some(r#"{"prompt": "an object please", "max_tokens": 32, "seed": 3}"#),
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(parse(&body).unwrap().get("grammar").unwrap().as_str(), Some("json"));

    // Metrics must reflect the finished request and parse line-by-line.
    let (status, text) = fetch(addr.as_str(), "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mut finished = None;
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').expect("name value");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
        assert!(v.is_finite(), "{line}");
        if name == "syncode_requests_finished_total" {
            finished = Some(v);
        }
    }
    assert!(finished.unwrap_or(0.0) >= 1.0, "no finished requests in metrics");
    assert!(text.contains("syncode_http_responses_total{code=\"200\"}"));
    assert!(text.contains("syncode_queue_capacity 64"));
    // Fault-tolerance families are exported even when everything is healthy.
    assert!(text.contains("syncode_replicas_live 1"), "{text}");
    assert!(text.contains("syncode_replicas_total 1"), "{text}");
    assert!(text.contains("syncode_replica_restarts_total 0"), "{text}");
    assert!(text.contains("syncode_lane_failures_total 0"), "{text}");
    assert!(
        text.contains("syncode_deadline_shed_queued_total{class=\"interactive\"} 0"),
        "{text}"
    );
    assert!(
        text.contains("syncode_deadline_exceeded_total{class=\"interactive\"} 0"),
        "{text}"
    );
    server.shutdown().shutdown();
}

#[test]
fn deadline_field_roundtrips_and_is_strictly_validated() {
    let (server, _reg, addr) = start_mock_http(1, 2, 64);
    let a = addr.as_str();

    // A generous deadline never fires: the request completes normally and
    // the response surfaces a natural finish reason.
    let body = r#"{"grammar": "calc", "prompt": "sum", "max_tokens": 16, "seed": 2,
                   "deadline_ms": 60000}"#;
    let (status, resp) = fetch(a, "POST", "/v1/generate", Some(body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = parse(&resp).unwrap();
    let finish = v.get("finish").unwrap().as_str().unwrap();
    assert!(finish_from_str(finish).is_some(), "unknown finish: {finish}");
    assert_ne!(finish, "deadline_exceeded", "{resp}");
    assert_eq!(v.get("valid").unwrap().as_bool(), Some(true), "{resp}");

    // Strict wire validation: zero and non-integer deadlines are 400s,
    // not silent coercions.
    let post = |body: &str| fetch(a, "POST", "/v1/generate", Some(body)).unwrap();
    let (status, resp) = post(r#"{"grammar": "calc", "prompt": "p", "deadline_ms": 0}"#);
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("deadline_ms"), "{resp}");
    let (status, resp) = post(r#"{"grammar": "calc", "prompt": "p", "deadline_ms": "5s"}"#);
    assert_eq!(status, 400, "{resp}");
    let (status, resp) = post(r#"{"grammar": "calc", "prompt": "p", "deadline_ms": -5}"#);
    assert_eq!(status, 400, "{resp}");

    // The server survives the abuse.
    let (status, resp) = post(&generate_body("calc", 3, 8));
    assert_eq!(status, 200, "{resp}");
    server.shutdown().shutdown();
}

#[test]
fn malformed_requests_get_4xx_and_server_survives() {
    let (server, _reg, addr) = start_mock_http(1, 2, 64);
    let a = addr.as_str();

    // Wire-level garbage.
    assert_eq!(raw_roundtrip(a, b"garbage\r\n\r\n").0, 400);
    assert_eq!(raw_roundtrip(a, b"GET /healthz FTP/1.1\r\n\r\n").0, 400);
    assert_eq!(raw_roundtrip(a, b"POST /v1/generate HTTP/1.1\r\n\r\n").0, 411);
    assert_eq!(
        raw_roundtrip(a, b"POST /v1/generate HTTP/1.1\r\nContent-Length: 99\r\n\r\n{").0,
        400 // body shorter than declared
    );
    let huge = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        10 * 1024 * 1024
    );
    assert_eq!(raw_roundtrip(a, huge.as_bytes()).0, 413);

    // Routing.
    assert_eq!(fetch(a, "GET", "/nope", None).unwrap().0, 404);
    assert_eq!(fetch(a, "GET", "/v1/generate", None).unwrap().0, 405);
    assert_eq!(fetch(a, "POST", "/metrics", Some("{}")).unwrap().0, 405);

    // Schema-level failures (all handled by net/json.rs).
    let post = |body: &str| fetch(a, "POST", "/v1/generate", Some(body)).unwrap();
    assert_eq!(post("not json").0, 400);
    assert_eq!(post("{\"prompt\": ").0, 400);
    assert_eq!(post(r#"{"max_tokens": 5}"#).0, 400); // missing prompt
    assert_eq!(post(r#"{"prompt": "p", "max_tokens": "ten"}"#).0, 400);
    assert_eq!(post(r#"{"prompt": "p", "max_token": 5}"#).0, 400); // typo field
    let (status, body) = post(r#"{"prompt": "p", "grammar": "sql2"}"#);
    assert_eq!(status, 400);
    assert!(body.contains("calc"), "error should list registered grammars: {body}");

    // After all that abuse the server still serves.
    let (status, body) = post(&generate_body("calc", 5, 24));
    assert_eq!(status, 200, "{body}");
    assert_eq!(fetch(a, "GET", "/healthz", None).unwrap().0, 200);
    server.shutdown().shutdown();
}

#[test]
fn utf8_and_escapes_roundtrip_through_the_wire() {
    let (server, _reg, addr) = start_mock_http(1, 2, 64);
    let body = r#"{"grammar": "json", "seed": 1, "max_tokens": 24,
                   "prompt": "héllo ☃ 😀 \"quoted\" back\\slash\nnewline"}"#;
    let (status, resp) = fetch(addr.as_str(), "POST", "/v1/generate", Some(body)).unwrap();
    assert_eq!(status, 200, "{resp}");
    let v = parse(&resp).unwrap();
    assert_eq!(v.get("valid").unwrap().as_bool(), Some(true));
    server.shutdown().shutdown();
}

// --------------------------------------------------------------------------
// Backpressure and shutdown need a model whose decode can be held open
// deterministically.

struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// A model whose first decode signals `entered` and then blocks until the
/// gate opens — pinning its (single) lane so the admission queue fills
/// deterministically. Logits are uniform; the grammar mask does all the
/// shaping.
struct StallModel {
    vocab: usize,
    gate: Arc<Gate>,
    entered: Option<Sender<()>>,
}

impl LanguageModel for StallModel {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn lanes(&self) -> usize {
        1
    }

    fn max_seq(&self) -> usize {
        256
    }

    fn prefill(&mut self, _lane: usize, _tokens: &[u32]) -> syncode::util::error::Result<Vec<f32>> {
        Ok(vec![0.0; self.vocab])
    }

    fn decode(
        &mut self,
        last: &[Option<u32>],
    ) -> syncode::util::error::Result<Vec<Option<Vec<f32>>>> {
        if let Some(tx) = self.entered.take() {
            let _ = tx.send(());
        }
        self.gate.wait();
        Ok(last.iter().map(|t| t.map(|_| vec![0.0; self.vocab])).collect())
    }

    fn release(&mut self, _lane: usize) {}

    fn name(&self) -> &'static str {
        "stall"
    }
}

/// HTTP front over a single stalling replica with a 1-deep admission
/// queue. Returns `(server, addr, gate, entered_rx)`.
fn start_stalled_http(queue_cap: usize) -> (HttpServer, String, Arc<Gate>, Receiver<()>) {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let gate = Gate::new();
    let (etx, erx) = channel();
    let vocab = tok.vocab_size();
    let gate_m = gate.clone();
    let entered = Arc::new(Mutex::new(Some(etx)));
    let factories = replicate_factory(1, move || {
        Ok(Box::new(StallModel {
            vocab,
            gate: gate_m.clone(),
            entered: entered.lock().unwrap().take(),
        }) as Box<dyn LanguageModel>)
    });
    let cfg = CoordinatorConfig { mask_threads: 0, queue_cap, ..Default::default() };
    let handle = Coordinator::start(factories, tok, reg.clone(), cfg);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        handle,
        reg,
        HttpConfig { workers: 6, ..Default::default() },
    )
    .expect("bind");
    let addr = server.local_addr().to_string();
    (server, addr, gate, erx)
}

#[test]
fn full_queue_maps_to_429_and_drains_after() {
    let (server, addr, gate, entered) = start_stalled_http(1);

    // A: admitted into the only lane, stalls inside decode.
    let addr_a = addr.clone();
    let a = std::thread::spawn(move || {
        fetch(addr_a.as_str(), "POST", "/v1/generate", Some(&generate_body("json", 1, 2)))
            .expect("request A")
    });
    entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");

    // B: sits in the admission queue, filling it (cap 1).
    let addr_b = addr.clone();
    let b = std::thread::spawn(move || {
        fetch(addr_b.as_str(), "POST", "/v1/generate", Some(&generate_body("json", 2, 2)))
            .expect("request B")
    });
    poll_until(30, "queue depth 1", || healthz_queue_depth(&addr) == 1);

    // C: queue full — backpressure must surface as 429, immediately.
    let (status, body) =
        fetch(addr.as_str(), "POST", "/v1/generate", Some(&generate_body("calc", 3, 2)))
            .unwrap();
    assert_eq!(status, 429, "{body}");
    assert!(parse(&body).unwrap().get("error").is_some());

    // Open the gate: A and B must both complete with valid output.
    gate.release();
    for (label, t) in [("A", a), ("B", b)] {
        let (status, body) = t.join().expect("client thread");
        assert_eq!(status, 200, "request {label}: {body}");
        assert_eq!(
            parse(&body).unwrap().get("valid").unwrap().as_bool(),
            Some(true),
            "request {label}: {body}"
        );
    }

    // The 429 is visible on /metrics.
    let (_, text) = fetch(addr.as_str(), "GET", "/metrics", None).unwrap();
    assert!(text.contains("syncode_http_responses_total{code=\"429\"} 1"), "{text}");
    server.shutdown().shutdown();
}

#[test]
fn dead_coordinator_maps_to_503() {
    // The only replica's model fails to construct → the replica guard
    // closes the queue → HTTP must answer 503, not hang or panic.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let factories: Vec<ModelFactory> =
        vec![Box::new(|| Err(syncode::util::error::Error::msg("no accelerator")))];
    let handle =
        Coordinator::start(factories, tok, reg.clone(), CoordinatorConfig::default());
    let server = HttpServer::bind(
        "127.0.0.1:0",
        handle,
        reg,
        HttpConfig { workers: 2, ..Default::default() },
    )
    .expect("bind");
    let addr = server.local_addr().to_string();

    poll_until(30, "coordinator closed", || {
        fetch(addr.as_str(), "GET", "/healthz", None).unwrap().0 == 503
    });
    let (status, body) =
        fetch(addr.as_str(), "POST", "/v1/generate", Some(&generate_body("json", 1, 8)))
            .unwrap();
    assert_eq!(status, 503, "{body}");
    server.shutdown().shutdown();
}

// --------------------------------------------------------------------------
// Streaming (SSE over chunked transfer-encoding) and keep-alive.

/// Collected result of one SSE generation: the token events in arrival
/// order and the parsed `done` payload.
struct StreamedGen {
    token_texts: Vec<String>,
    token_count: usize,
    done: Json,
}

/// Drive one `?stream=1` request to completion on `client`.
fn consume_stream(client: &mut HttpClient, body: &str) -> StreamedGen {
    let mut stream = client
        .request_stream("POST", "/v1/generate?stream=1", Some(body))
        .expect("stream request");
    assert_eq!(stream.status(), 200, "stream refused");
    let mut token_texts = Vec::new();
    let mut token_count = 0usize;
    let mut done = None;
    while let Some((event, data)) = stream.next_event().expect("sse event") {
        match event.as_str() {
            "token" => {
                let v = parse(&data).expect("token event json");
                assert_eq!(
                    v.get("index").and_then(Json::as_usize),
                    Some(token_count),
                    "token indices must be dense: {data}"
                );
                token_texts
                    .push(v.get("text").and_then(Json::as_str).unwrap_or("").to_string());
                token_count += 1;
            }
            "done" => {
                assert!(done.is_none(), "multiple done events");
                done = Some(parse(&data).expect("done event json"));
            }
            other => panic!("unexpected SSE event {other}: {data}"),
        }
    }
    StreamedGen { token_texts, token_count, done: done.expect("stream ended without done") }
}

#[test]
fn streaming_tokens_arrive_before_generation_completes() {
    // Gate-stalled model: the first decode blocks, so the generation
    // cannot finish (max_tokens 3 needs decoded logits for tokens 2+) —
    // yet the first token's SSE event, decided from the prefill logits,
    // must reach the client while the gate is still closed.
    let (server, addr, gate, entered) = start_stalled_http(4);
    let mut client = HttpClient::connect(addr.as_str()).expect("connect");
    let mut stream = client
        .request_stream(
            "POST",
            "/v1/generate?stream=1",
            Some(&generate_body("json", 5, 3)),
        )
        .expect("stream request");
    assert_eq!(stream.status(), 200);
    let (event, data) = stream
        .next_event()
        .expect("read first event")
        .expect("stream ended before any token");
    assert_eq!(event, "token", "first event must be a token: {data}");
    // The model is provably still inside (or entering) its first decode:
    // the gate has never been released, so the generation is incomplete.
    entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");
    gate.release();
    // The rest of the stream completes normally.
    let mut saw_done = false;
    while let Some((event, data)) = stream.next_event().expect("sse event") {
        if event == "done" {
            let v = parse(&data).expect("done json");
            assert_eq!(v.get("valid").and_then(Json::as_bool), Some(true), "{data}");
            saw_done = true;
        }
    }
    assert!(saw_done, "stream must terminate with a done event");
    // Free the keep-alive connection before the drain (an idle one would
    // only release its worker at the read deadline).
    drop(stream);
    drop(client);
    server.shutdown().shutdown();
}

#[test]
fn mid_stream_disconnect_frees_the_lane() {
    // One lane, stalled in decode. Client A starts a long stream, reads
    // its first token, then drops the connection. Once the gate opens the
    // replica's next event send fails, the lane is cancelled and freed —
    // client B's request (queued behind A) must then complete normally.
    let (server, addr, gate, entered) = start_stalled_http(4);
    {
        let mut a = HttpClient::connect(addr.as_str()).expect("connect A");
        // A 12-deep array prefix: the grammar cannot reach a complete
        // value (and thus EOS) for at least 12 more tokens, so the
        // disconnect is detected — one buffered write to the dead socket,
        // then a failed one, then a failed event send — long before the
        // generation could finish on its own.
        let body = r#"{"grammar": "json", "prompt": "deep", "max_tokens": 64, "seed": 7,
                       "prefix": "[[[[[[[[[[[["}"#;
        let mut stream = a
            .request_stream("POST", "/v1/generate?stream=1", Some(body))
            .expect("stream request");
        assert_eq!(stream.status(), 200);
        let (event, _) = stream
            .next_event()
            .expect("read first event")
            .expect("stream ended before any token");
        assert_eq!(event, "token");
        entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");
        // A disconnects mid-stream (drop closes the socket).
    }
    // B queues behind the pinned lane.
    let addr_b = addr.clone();
    let b = std::thread::spawn(move || {
        fetch(addr_b.as_str(), "POST", "/v1/generate", Some(&generate_body("json", 8, 2)))
            .expect("request B")
    });
    poll_until(30, "B queued", || healthz_queue_depth(&addr) >= 1);
    gate.release();
    let (status, body) = b.join().expect("client B thread");
    assert_eq!(status, 200, "lane was not freed for B: {body}");
    assert_eq!(parse(&body).unwrap().get("valid").unwrap().as_bool(), Some(true));
    // The cancellation is visible on /metrics.
    poll_until(30, "cancel metric", || {
        let (_, text) = fetch(addr.as_str(), "GET", "/metrics", None).unwrap();
        text.contains("syncode_streams_cancelled_total 1")
    });
    server.shutdown().shutdown();
}

#[test]
fn keepalive_connection_serves_sequential_requests() {
    let (server, _reg, addr) = start_mock_http(1, 2, 64);
    let mut client = HttpClient::connect(addr.as_str()).expect("connect");
    // Mixed sequential traffic — generations, health, a stream, metrics —
    // all down one connection; any dropped keep-alive would surface as a
    // read error on the next request.
    for i in 0..3u64 {
        let g = if i % 2 == 0 { "json" } else { "calc" };
        let (status, body) = client
            .request("POST", "/v1/generate", Some(&generate_body(g, i, 24)))
            .expect("keep-alive generate");
        assert_eq!(status, 200, "request {i}: {body}");
        assert_eq!(parse(&body).unwrap().get("valid").unwrap().as_bool(), Some(true));
    }
    let (status, _) = client.request("GET", "/healthz", None).expect("healthz");
    assert_eq!(status, 200);
    // A stream in the middle must leave the connection reusable (the
    // chunked terminator delimits it exactly).
    let streamed = consume_stream(&mut client, &generate_body("json", 4, 16));
    assert!(streamed.token_count > 0);
    let (status, text) = client.request("GET", "/metrics", None).expect("metrics");
    assert_eq!(status, 200);
    assert!(text.contains("syncode_requests_finished_total"));
    drop(client);
    server.shutdown().shutdown();
}

#[test]
fn stream_and_blocking_outputs_are_byte_identical_per_seed() {
    let (server, _reg, addr) = start_mock_http(1, 2, 64);
    // Greedy decoding: deterministic for a fixed seed regardless of the
    // server-assigned request id, so the two modes must match exactly.
    let body = r#"{"grammar": "json", "prompt": "a record", "max_tokens": 40,
                   "seed": 9, "strategy": "greedy"}"#;
    let (status, blocking) =
        fetch(addr.as_str(), "POST", "/v1/generate", Some(body)).expect("blocking request");
    assert_eq!(status, 200, "{blocking}");
    let blocking = parse(&blocking).expect("blocking json");
    let blocking_text = blocking.get("text").unwrap().as_str().unwrap();

    let mut client = HttpClient::connect(addr.as_str()).expect("connect");
    let streamed = consume_stream(&mut client, body);
    let done_text = streamed.done.get("text").unwrap().as_str().unwrap();

    assert_eq!(done_text, blocking_text, "stream vs blocking text diverged");
    assert_eq!(
        streamed.done.get("finish").unwrap().as_str(),
        blocking.get("finish").unwrap().as_str()
    );
    assert_eq!(
        streamed.done.get("tokens").and_then(Json::as_usize),
        blocking.get("tokens").and_then(Json::as_usize)
    );
    assert_eq!(streamed.done.get("valid").unwrap().as_bool(), Some(true));
    // The incremental chunks (+ the done event's UTF-8 tail, normally
    // empty) reassemble the final text byte-for-byte.
    let tail = streamed.done.get("tail").and_then(Json::as_str).unwrap_or("");
    assert_eq!(streamed.token_texts.concat() + tail, done_text);
    assert_eq!(Some(streamed.token_count), blocking.get("tokens").and_then(Json::as_usize));
    drop(client);
    server.shutdown().shutdown();
}

// --------------------------------------------------------------------------
// SLO classes: strict-priority admission must let an interactive request
// jump a batch flood the moment a lane frees.

/// A decode-permit gate: every batched decode call consumes one permit,
/// blocking until one is granted. Unlike [`Gate`] (one-shot open), this
/// lets a test advance the single replica exactly one decode at a time
/// and inspect the scheduler's admission decisions in a stable state.
struct PermitGate {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl PermitGate {
    fn new() -> Arc<PermitGate> {
        Arc::new(PermitGate { permits: Mutex::new(0), cv: Condvar::new() })
    }

    fn grant(&self, n: usize) {
        *self.permits.lock().unwrap() += n;
        self.cv.notify_all();
    }

    fn take(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }
}

/// Uniform-logits model that announces and then consumes one permit per
/// batched decode call. Prefill is free, so admission (and the inline
/// first-token decision) always proceeds; only decode steps are metered.
struct PermitModel {
    vocab: usize,
    gate: Arc<PermitGate>,
    entered: Sender<()>,
}

impl LanguageModel for PermitModel {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn lanes(&self) -> usize {
        1
    }

    fn max_seq(&self) -> usize {
        256
    }

    fn prefill(&mut self, _lane: usize, _tokens: &[u32]) -> syncode::util::error::Result<Vec<f32>> {
        Ok(vec![0.0; self.vocab])
    }

    fn decode(
        &mut self,
        last: &[Option<u32>],
    ) -> syncode::util::error::Result<Vec<Option<Vec<f32>>>> {
        let _ = self.entered.send(());
        self.gate.take();
        Ok(last.iter().map(|t| t.map(|_| vec![0.0; self.vocab])).collect())
    }

    fn release(&mut self, _lane: usize) {}

    fn name(&self) -> &'static str {
        "permit"
    }
}

fn healthz_class_depths(addr: &str) -> (usize, usize) {
    let (_, body) = fetch(addr, "GET", "/healthz", None).expect("healthz");
    let v = parse(&body).unwrap_or(Json::Null);
    let d = v.get("queue_class_depths").cloned().unwrap_or(Json::Null);
    (
        d.get("interactive").and_then(Json::as_usize).unwrap_or(usize::MAX),
        d.get("batch").and_then(Json::as_usize).unwrap_or(usize::MAX),
    )
}

#[test]
fn batch_flood_does_not_starve_interactive() {
    // Deep-bracket prefixes pin every request to exactly 2 tokens and
    // exactly 1 decode call (the grammar cannot reach EOS inside 4 more
    // tokens, so the first token comes from prefill logits and the second
    // from the single metered decode → MaxTokens). That makes the permit
    // accounting exact and the scheduling assertions deterministic.
    // Aging is parked out of reach (60s) so only strict priority acts.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let gate = PermitGate::new();
    let (etx, entered) = channel();
    let vocab = tok.vocab_size();
    let gate_m = gate.clone();
    let factories = replicate_factory(1, move || {
        Ok(Box::new(PermitModel { vocab, gate: gate_m.clone(), entered: etx.clone() })
            as Box<dyn LanguageModel>)
    });
    let cfg = CoordinatorConfig {
        mask_threads: 0,
        queue_cap: 16,
        batch_age_ms: 60_000,
        ..Default::default()
    };
    let handle = Coordinator::start(factories, tok, reg.clone(), cfg);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        handle,
        reg,
        HttpConfig { workers: 8, ..Default::default() },
    )
    .expect("bind");
    let addr = server.local_addr().to_string();

    // A (interactive by default) takes the only lane and stalls in its
    // single decode.
    let body_a = r#"{"grammar": "json", "prompt": "pin", "max_tokens": 2, "seed": 1,
                     "prefix": "[[[["}"#;
    let addr_a = addr.clone();
    let a = std::thread::spawn(move || {
        fetch(addr_a.as_str(), "POST", "/v1/generate", Some(body_a)).expect("request A")
    });
    entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");

    // A batch-class flood queues behind it...
    let flood: Vec<_> = (0..3u64)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"grammar": "calc", "prompt": "bulk #{i}", "max_tokens": 2,
                        "seed": {i}, "prefix": "((((", "priority": "batch"}}"#
                );
                fetch(addr.as_str(), "POST", "/v1/generate", Some(&body)).expect("batch req")
            })
        })
        .collect();
    poll_until(30, "flood queued", || healthz_class_depths(&addr) == (0, 3));

    // ...then one interactive request arrives BEHIND the whole flood.
    let body_i = r#"{"grammar": "json", "prompt": "now", "max_tokens": 2, "seed": 9,
                     "prefix": "[[[["}"#;
    let addr_i = addr.clone();
    let interactive = std::thread::spawn(move || {
        fetch(addr_i.as_str(), "POST", "/v1/generate", Some(body_i)).expect("interactive")
    });
    poll_until(30, "interactive queued", || healthz_class_depths(&addr) == (1, 3));

    // One permit: A finishes, freeing the lane; continuous admission must
    // dequeue the interactive request PAST the three older batch entries.
    // The admitted request then blocks in its own decode, so the state is
    // stable: interactive left the queue, the flood did not move.
    gate.grant(1);
    entered.recv_timeout(Duration::from_secs(30)).expect("no successor admitted");
    let (status, body) = a.join().expect("thread A");
    assert_eq!(status, 200, "{body}");
    assert_eq!(
        healthz_class_depths(&addr),
        (0, 3),
        "strict priority must admit the interactive request first"
    );
    let (_, text) = fetch(addr.as_str(), "GET", "/metrics", None).unwrap();
    assert!(
        text.contains("syncode_class_requests_finished_total{class=\"interactive\"} 1"),
        "only A should have finished: {text}"
    );
    assert!(
        text.contains("syncode_class_requests_finished_total{class=\"batch\"} 0"),
        "no batch request may have finished: {text}"
    );

    // Open the tap: the flood drains too (no starvation in either
    // direction once the interactive traffic is gone).
    gate.grant(16);
    let (status, body) = interactive.join().expect("interactive thread");
    assert_eq!(status, 200, "{body}");
    for t in flood {
        let (status, body) = t.join().expect("flood thread");
        assert_eq!(status, 200, "{body}");
    }
    let (_, text) = fetch(addr.as_str(), "GET", "/metrics", None).unwrap();
    assert!(
        text.contains("syncode_class_requests_finished_total{class=\"batch\"} 3"),
        "{text}"
    );
    assert!(
        text.contains("syncode_class_aged_promotions_total{class=\"batch\"} 0"),
        "aging must not have fired with a 60s bound: {text}"
    );
    server.shutdown().shutdown();
}

#[test]
fn graceful_shutdown_completes_inflight_requests() {
    let (server, addr, gate, entered) = start_stalled_http(4);

    // An in-flight generation, pinned inside the model.
    let addr_a = addr.clone();
    let a = std::thread::spawn(move || {
        fetch(addr_a.as_str(), "POST", "/v1/generate", Some(&generate_body("json", 9, 2)))
            .expect("in-flight request")
    });
    entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");

    // Shutdown arrives while it is still decoding.
    let (status, body) =
        fetch(addr.as_str(), "POST", "/admin/shutdown", Some("{}")).unwrap();
    assert_eq!(status, 200, "{body}");

    // The drain must wait for the lane, not drop it.
    gate.release();
    let handle = server.wait();
    let (status, body) = a.join().expect("client thread");
    assert_eq!(status, 200, "in-flight request lost in shutdown: {body}");
    assert_eq!(parse(&body).unwrap().get("valid").unwrap().as_bool(), Some(true));

    // Workers are gone: the port no longer accepts requests.
    assert!(fetch(addr.as_str(), "GET", "/healthz", None).is_err());
    assert_eq!(handle.snapshot().requests_finished, 1);
    handle.shutdown();
}
