//! Cross-validation property tests:
//!
//! - the LR(1)/LALR(1) tables against an independent **Earley recogniser**
//!   (implemented here, just for testing) on random grammars and random
//!   strings — table-generation bugs cannot hide behind the engine tests;
//! - lexer/mask invariants under random fuzzing.

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar, GrammarRegistry};
use syncode::engine::GrammarContext;
use syncode::grammar::{parse_ebnf, CompileLimits, Grammar, Symbol, TermId};
use syncode::lexer::Lexer;
use syncode::parser::{LrMode, LrTable, ParserState};
use syncode::tokenizer::Tokenizer;
use syncode::util::rng::Rng;

// ------------------------------------------------------ earley recogniser --

/// Earley recognition over terminal sequences (no parse trees; test only).
fn earley_accepts(g: &Grammar, input: &[TermId]) -> bool {
    #[derive(Clone, PartialEq)]
    struct Item {
        rule: usize,
        dot: usize,
        start: usize,
    }
    let n = input.len();
    let mut sets: Vec<Vec<Item>> = vec![Vec::new(); n + 1];
    // seed with start productions
    for &r in &g.rules_by_lhs[g.start as usize] {
        sets[0].push(Item { rule: r as usize, dot: 0, start: 0 });
    }
    for i in 0..=n {
        let mut idx = 0;
        while idx < sets[i].len() {
            let it = sets[i][idx].clone();
            idx += 1;
            let rhs = &g.rules[it.rule].rhs;
            match rhs.get(it.dot) {
                Some(Symbol::N(nt)) => {
                    // predict
                    for &r in &g.rules_by_lhs[*nt as usize] {
                        let cand = Item { rule: r as usize, dot: 0, start: i };
                        if !sets[i].contains(&cand) {
                            sets[i].push(cand);
                        }
                    }
                    // magical completion for nullable nonterminals: handled
                    // by the completer below since ε-rules complete in-place.
                }
                Some(Symbol::T(t)) => {
                    if i < n && input[i] == *t {
                        let cand = Item { rule: it.rule, dot: it.dot + 1, start: it.start };
                        if !sets[i + 1].contains(&cand) {
                            sets[i + 1].push(cand);
                        }
                    }
                }
                None => {
                    // complete
                    let lhs = g.rules[it.rule].lhs;
                    let parents: Vec<Item> = sets[it.start]
                        .iter()
                        .filter(|p| {
                            g.rules[p.rule].rhs.get(p.dot) == Some(&Symbol::N(lhs))
                        })
                        .cloned()
                        .collect();
                    for p in parents {
                        let cand = Item { rule: p.rule, dot: p.dot + 1, start: p.start };
                        if !sets[i].contains(&cand) {
                            sets[i].push(cand);
                        }
                    }
                }
            }
        }
    }
    sets[n].iter().any(|it| {
        g.rules[it.rule].lhs == g.start
            && it.dot == g.rules[it.rule].rhs.len()
            && it.start == 0
    })
}

/// LR acceptance of a terminal sequence.
fn lr_accepts(table: &Arc<LrTable>, input: &[TermId]) -> bool {
    let mut p = ParserState::new(table.clone());
    for &t in input {
        if !p.next(t) {
            return false;
        }
    }
    p.accepts_eof()
}

/// Random small grammar sources (unambiguous-by-construction shapes).
fn random_grammar_src(rng: &mut Rng) -> String {
    // Pick one of several templates with randomised terminals.
    let a = ["x", "y", "z", "w"][rng.below(4)];
    let b = ["p", "q", "r"][rng.below(3)];
    match rng.below(4) {
        0 => format!("start: list\nlist: \"{a}\" | list \",\" \"{a}\"\n"),
        1 => format!(
            "start: e\ne: t | e \"+\" t\nt: \"{a}\" | \"(\" e \")\"\n"
        ),
        2 => format!(
            "start: s\ns: \"{a}\" s \"{b}\" | \"m\"\n" // aⁿ m bⁿ
        ),
        _ => format!(
            "start: r\nr: \"{a}\" opt\nopt: | \"{b}\" r\n" // (a b)* a-ish chain
        ),
    }
}

fn random_grammar(rng: &mut Rng) -> Grammar {
    parse_ebnf(&random_grammar_src(rng)).unwrap()
}

#[test]
fn lr_agrees_with_earley_on_random_grammars() {
    let mut rng = Rng::new(99);
    for case in 0..40 {
        let g = random_grammar(&mut rng);
        for mode in [LrMode::Canonical, LrMode::Lalr] {
            let table = Arc::new(LrTable::build(&g, mode));
            assert!(table.conflicts.is_empty(), "case {case}: {:?}", table.conflicts);
            let nterms = g.terminals.len() as u16;
            for _ in 0..60 {
                let len = rng.below(8);
                let input: Vec<TermId> =
                    (0..len).map(|_| rng.below(nterms as usize) as TermId).collect();
                assert_eq!(
                    lr_accepts(&table, &input),
                    earley_accepts(&g, &input),
                    "case {case} {mode:?}: disagree on {input:?} for grammar {:?}",
                    g.rules.iter().map(|r| g.rule_to_string(r)).collect::<Vec<_>>()
                );
            }
        }
    }
}

#[test]
fn builtin_grammars_lr_matches_earley_on_token_streams() {
    // Drive real grammar token streams (from lexing corpus docs) through
    // both recognisers.
    let mut rng = Rng::new(7);
    for gname in ["json", "calc", "sql"] {
        let g = Grammar::builtin(gname).unwrap();
        let table = Arc::new(LrTable::build(&g, LrMode::Lalr));
        let lexer = Lexer::new(&g);
        for doc in syncode::eval::dataset::corpus(gname, 12, 31) {
            let lr = lexer.lex(&doc);
            assert!(lr.error.is_none());
            let mut terms: Vec<TermId> =
                lr.tokens.iter().filter(|t| !t.ignored).map(|t| t.term).collect();
            if let Some(t) = lr.remainder_term {
                if !g.terminals[t as usize].ignore {
                    terms.push(t);
                }
            }
            assert!(earley_accepts(&g, &terms), "{gname}: earley rejects corpus doc");
            assert!(lr_accepts(&table, &terms), "{gname}: LR rejects corpus doc");
            // Mutate: drop a random token — both must agree (usually reject).
            if !terms.is_empty() {
                let mut broken = terms.clone();
                broken.remove(rng.below(broken.len()));
                assert_eq!(
                    lr_accepts(&table, &broken),
                    earley_accepts(&g, &broken),
                    "{gname}: disagree on mutated stream"
                );
            }
        }
    }
}

#[test]
fn lexer_never_loses_bytes() {
    // Fuzz: tokens + remainder always cover the input contiguously.
    let mut rng = Rng::new(13);
    let g = Grammar::builtin("json").unwrap();
    let lexer = Lexer::new(&g);
    let alphabet: Vec<u8> = br#"{}[]:,"0123456789.eE+-truefalsn "#.to_vec();
    for _ in 0..300 {
        let len = rng.below(40);
        let input: Vec<u8> = (0..len).map(|_| *rng.choose(&alphabet)).collect();
        let r = lexer.lex(&input);
        let mut pos = 0;
        for t in &r.tokens {
            assert_eq!(t.start, pos, "gap before token in {input:?}");
            assert!(t.end > t.start);
            pos = t.end;
        }
        match r.error {
            Some(_) => {}
            None => assert_eq!(r.remainder_start, pos, "remainder gap in {input:?}"),
        }
    }
}

#[test]
fn accepted_grammars_roundtrip_through_artifact_bytes() {
    // Every grammar the untrusted-input surface ACCEPTS must survive the
    // full persistence cycle — compile → SYNCART1 serialise → load —
    // with a byte-identical artifact (and therefore byte-identical mask
    // store): what a warm restart serves is exactly what was compiled.
    let mut rng = Rng::new(41);
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let cfg = ArtifactConfig::default();
    let limits = CompileLimits::default();
    for case in 0..12 {
        let src = random_grammar_src(&mut rng);
        let art =
            CompiledGrammar::compile_ebnf_limited("rt", &src, tok.clone(), &cfg, &limits)
                .unwrap_or_else(|e| panic!("case {case}: accepted template failed: {e}"));
        let blob = art.to_bytes();
        let back = CompiledGrammar::from_bytes(&blob)
            .unwrap_or_else(|e| panic!("case {case}: roundtrip load failed: {e}"));
        assert_eq!(blob, back.to_bytes(), "case {case}: reserialisation diverged ({src:?})");
        assert_eq!(art.source, back.source);
        assert_eq!(art.store.stats.unique_masks, back.store.stats.unique_masks);
        assert_eq!(art.store.stats.mem_bytes, back.store.stats.mem_bytes);
        assert!(back.compile_stats.from_cache);
        // The loaded artifact answers exactly like the compiled one.
        for _ in 0..20 {
            let len = rng.below(6);
            let probe: Vec<u8> =
                (0..len).map(|_| *rng.choose(b"xyzwpqr,+()m ")).collect();
            assert_eq!(
                art.cx.prefix_valid(&probe),
                back.cx.prefix_valid(&probe),
                "case {case}: oracle diverged on {probe:?}"
            );
        }
    }
}

#[test]
fn rejected_grammars_leave_no_partial_registry_entry() {
    // The registration path is atomic: an input rejected at ANY stage
    // (wire name rule, parse, limits) yields a clean error and the
    // registry is exactly as it was — no half-registered grammar, no
    // changed default, nothing evicted.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let cfg = ArtifactConfig::default();
    let reg = Arc::new(GrammarRegistry::new());
    reg.register(CompiledGrammar::compile("calc", tok, &cfg).unwrap()).unwrap();
    let limits = CompileLimits::default();
    let names_before = reg.names();
    let default_before = reg.default_grammar().unwrap().name.clone();
    let errors_before = reg.stats().compile_errors;

    let big_regex = format!("start: A\nA: /{}/\n", "a".repeat(5000)); // regex byte cap
    let deep = format!("start: {}a{}\na: \"x\"\n", "(".repeat(600), ")".repeat(600)); // depth cap
    let oversize = format!("start: A\nA: \"a\"\n{}", "// pad\n".repeat(50_000)); // source cap
    let hostile: Vec<(&str, &str)> = vec![
        ("bad name", "start: A\nA: /a/\n"),            // rejected by the name rule
        ("broken", "start: %%% nope"),                  // parse error
        ("truncated", "start: item\nitem: \"unclosed"), // lexer error
        ("bigregex", &big_regex),
        ("deep", &deep),
        ("oversize", &oversize),
    ];
    for &(name, src) in &hostile {
        let err = match syncode::artifact::compile_and_register(
            &reg,
            name,
            src,
            &cfg,
            &limits,
            None,
        ) {
            Ok(_) => panic!("hostile grammar '{name}' was accepted"),
            Err(e) => e,
        };
        assert!(!err.to_string().is_empty());
        assert!(reg.get(name).is_none(), "partial entry for '{name}'");
    }
    assert_eq!(reg.names(), names_before, "registry contents changed");
    assert_eq!(reg.default_grammar().unwrap().name, default_before);
    assert_eq!(reg.stats().evictions, 0);
    assert_eq!(
        reg.stats().compile_errors,
        errors_before + hostile.len() as u64,
        "every rejection must be tallied exactly once"
    );
}

#[test]
fn prefix_validity_monotone_under_truncation() {
    // Every prefix of a valid document is a valid prefix (L_p(G) is
    // prefix-closed by definition) — checks lexer+parser+accept plumbing.
    for gname in ["json", "calc", "sql", "python", "go"] {
        let cx = GrammarContext::builtin(gname, LrMode::Lalr).unwrap();
        for doc in syncode::eval::dataset::corpus(gname, 6, 17) {
            for cut in 0..=doc.len() {
                assert!(
                    cx.prefix_valid(&doc[..cut]),
                    "{gname}: prefix of valid doc rejected at {cut}: {:?}",
                    String::from_utf8_lossy(&doc[..cut])
                );
            }
        }
    }
}
