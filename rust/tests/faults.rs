//! Fault-tolerance acceptance suite, driven by the deterministic
//! injection harness (`coordinator::faults`): a panicking lane never
//! corrupts sibling lanes' bytes, a killed replica is respawned and the
//! queue keeps draining, per-request deadlines shed queued work and cut
//! running work while freeing capacity, and a vanished stream consumer
//! cancels its generation.

use std::collections::HashMap;
use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar, GrammarRegistry};
use syncode::coordinator::{
    Coordinator, CoordinatorConfig, FaultPlan, FaultyModel, FinishReason, GenParams,
    GenRequest, SloClass, Strategy, TokenEvent,
};
use syncode::runtime::{replicate_factory, LanguageModel, MockModel, ModelFactory};
use syncode::tokenizer::Tokenizer;

fn docs() -> Vec<Vec<u8>> {
    vec![
        br#"{"name": "alice", "age": 30}"#.to_vec(),
        br#"{"items": [1, 2, 3], "ok": true}"#.to_vec(),
        br#"{"nested": {"a": null}}"#.to_vec(),
        b"1 + 2 * 3".to_vec(),
        b"math_sqrt(4) - 1".to_vec(),
        b"(7 - 2) / 5".to_vec(),
    ]
}

fn registry(tok: &Arc<Tokenizer>) -> Arc<GrammarRegistry> {
    let reg = Arc::new(GrammarRegistry::new());
    for g in ["json", "calc"] {
        let art = CompiledGrammar::compile(g, tok.clone(), &ArtifactConfig::default()).unwrap();
        reg.register(art).unwrap();
    }
    reg
}

/// A single-replica factory wrapping the mock in a [`FaultyModel`]. The
/// plan's shared counters mean a supervisor respawn *continues* the
/// ordinal count — one-shot faults never refire in the new incarnation.
fn faulty_factory(tok: &Arc<Tokenizer>, lanes: usize, plan: FaultPlan) -> Vec<ModelFactory> {
    let tok = tok.clone();
    replicate_factory(1, move || {
        let inner = MockModel::from_documents(tok.clone(), &docs(), lanes, 256, 11);
        Ok(Box::new(FaultyModel::new(Box::new(inner), plan.clone()))
            as Box<dyn LanguageModel>)
    })
}

fn plain_factory(tok: &Arc<Tokenizer>, lanes: usize) -> Vec<ModelFactory> {
    faulty_factory(tok, lanes, FaultPlan::new())
}

fn request_spec(id: u64, grammar: &str, max_new_tokens: usize, spec_k: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: format!("produce {grammar} #{id}"),
        constraint_prefix: String::new(),
        grammar: Some(grammar.to_string()),
        params: GenParams {
            max_new_tokens,
            strategy: Strategy::TopP { temp: 0.85, p: 0.95 },
            seed: id * 13 + 7,
            opportunistic: id % 2 == 0,
            spec_k,
            ..Default::default()
        },
        token_sink: None,
    }
}

fn request(id: u64, grammar: &str, max_new_tokens: usize) -> GenRequest {
    request_spec(id, grammar, max_new_tokens, 0)
}

fn grammar_for(id: u64) -> &'static str {
    if id % 2 == 0 {
        "json"
    } else {
        "calc"
    }
}

#[test]
fn prefill_panic_fails_one_request_and_never_corrupts_siblings() {
    // One replica, two lanes, six requests; the 2nd prefill (request id
    // 1, admission is FIFO within a class) panics by plan. The poisoned
    // admission must finish `Failed` with exactly one terminal event,
    // and every *survivor* must be byte-identical to a no-fault run —
    // swept inline/pooled × spec_k {0, 4}, the panic fence must never
    // perturb sibling lanes' decisions.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);

    // The no-fault reference: (text, tokens) per id. The serving suite
    // separately pins that these bytes are invariant across the same
    // config sweep, so one baseline serves all four faulted configs.
    let mut baseline: HashMap<u64, (String, usize)> = HashMap::new();
    {
        let srv = Coordinator::start(
            plain_factory(&tok, 2),
            tok.clone(),
            reg.clone(),
            CoordinatorConfig::default(),
        );
        let rxs: Vec<_> =
            (0..6u64).map(|i| srv.submit(request(i, grammar_for(i), 32))).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            baseline.insert(resp.id, (resp.text, resp.tokens));
        }
        srv.shutdown();
    }

    for spec_k in [0usize, 4] {
        for mask_threads in [0usize, 2] {
            // Fresh plan per run: its ordinal counters are shared across
            // clones, so a consumed one-shot would not refire.
            let plan = FaultPlan::new().panic_on_prefill(2);
            let srv = Coordinator::start(
                faulty_factory(&tok, 2, plan),
                tok.clone(),
                reg.clone(),
                CoordinatorConfig { mask_threads, ..Default::default() },
            );
            // Per-request sinks prove exactly one terminal event each.
            let mut sinks = Vec::new();
            let rxs: Vec<_> = (0..6u64)
                .map(|i| {
                    let mut r = request_spec(i, grammar_for(i), 32, spec_k);
                    let (tx, rx_ev) = std::sync::mpsc::channel();
                    r.token_sink = Some(tx);
                    sinks.push((i, rx_ev));
                    srv.submit(r)
                })
                .collect();
            let mut failed = 0usize;
            for rx in rxs {
                let resp = rx.recv().unwrap();
                if resp.finish == FinishReason::Failed {
                    failed += 1;
                    assert_eq!(resp.id, 1, "the 2nd prefill is request 1");
                    assert!(
                        resp.error.as_deref().unwrap_or("").contains("panicked"),
                        "{:?}",
                        resp.error
                    );
                } else {
                    assert!(resp.error.is_none(), "req {}: {:?}", resp.id, resp.error);
                    assert_eq!(
                        baseline.get(&resp.id),
                        Some(&(resp.text.clone(), resp.tokens)),
                        "survivor {} diverged from the no-fault run \
                         (spec_k={spec_k}, mask_threads={mask_threads})",
                        resp.id
                    );
                }
            }
            assert_eq!(failed, 1, "exactly one admission fails");
            let snap = srv.snapshot();
            srv.shutdown();
            for (id, rx_ev) in sinks {
                let finished =
                    rx_ev.try_iter().filter(|e| matches!(e, TokenEvent::Finished { .. })).count();
                assert_eq!(finished, 1, "request {id}: exactly one terminal event");
            }
            assert_eq!(snap.lane_failures, 1);
            assert_eq!(snap.requests_finished, 6);
            assert_eq!(snap.replica_restarts, 0, "a prefill panic keeps the thread");
        }
    }
}

#[test]
fn decode_panic_respawns_replica_and_queue_keeps_draining() {
    // The 3rd decode-path step panics: the replica fails its active
    // lanes and exits; the supervisor must respawn it from the factory
    // (the shared-ordinal plan never refires) and the respawned replica
    // drains the rest of the queue.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let plan = FaultPlan::new().panic_on_step(3);
    let srv = Coordinator::start(
        faulty_factory(&tok, 2, plan),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig::default(),
    );
    let rxs: Vec<_> = (0..8u64).map(|i| srv.submit(request(i, grammar_for(i), 24))).collect();
    let mut failed = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("every request gets a response across the respawn");
        match resp.finish {
            FinishReason::Failed => {
                failed += 1;
                assert!(
                    resp.error.as_deref().unwrap_or("").contains("panicked"),
                    "{:?}",
                    resp.error
                );
            }
            _ => {
                assert!(resp.error.is_none(), "req {}: {:?}", resp.id, resp.error);
                let art = reg.get(grammar_for(resp.id)).unwrap();
                assert!(art.response_valid(&resp), "invalid survivor: {:?}", resp.text);
            }
        }
    }
    assert!(failed >= 1, "the panicking step had at least one active lane");
    assert_eq!(srv.replicas_live(), 1, "respawned replica is live");
    assert_eq!(srv.replicas_total(), 1);
    let snap = srv.snapshot();
    srv.shutdown();
    assert_eq!(snap.replica_restarts, 1, "exactly one supervisor respawn");
    assert_eq!(snap.lane_failures as usize, failed);
    assert_eq!(snap.requests_finished, 8, "no request was dropped");
}

#[test]
fn decode_error_fails_lanes_cleanly_without_restart() {
    // A clean `Err` from a decode step is an orderly backend failure:
    // active lanes finish EngineError, but the thread and the model are
    // kept — no supervisor respawn, and the queue keeps draining.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let plan = FaultPlan::new().error_on_step(2);
    let srv = Coordinator::start(
        faulty_factory(&tok, 2, plan),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig::default(),
    );
    let rxs: Vec<_> = (0..6u64).map(|i| srv.submit(request(i, grammar_for(i), 24))).collect();
    let mut errored = 0usize;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        if resp.finish == FinishReason::EngineError {
            errored += 1;
            assert!(
                resp.error.as_deref().unwrap_or("").contains("fault injection"),
                "{:?}",
                resp.error
            );
        } else {
            assert!(resp.error.is_none(), "req {}: {:?}", resp.id, resp.error);
        }
    }
    assert!(errored >= 1, "the failing step had at least one active lane");
    assert_eq!(srv.replicas_live(), 1);
    let snap = srv.snapshot();
    srv.shutdown();
    assert_eq!(snap.replica_restarts, 0, "a clean error must not trigger a respawn");
    assert_eq!(snap.lane_failures, 0);
    assert_eq!(snap.engine_errors as usize, errored);
    assert_eq!(snap.requests_finished, 6);
}

#[test]
fn deadline_cut_frees_the_lane_for_queued_interactive_work() {
    // One lane. A would run 64 tokens (a deep bracket prefix makes EOS
    // unreachable) but carries a 100 ms deadline; a 400 ms stall on its
    // 2nd step drives the clock past it deterministically. A must finish
    // DeadlineExceeded with partial output, and queued B must then get
    // the freed lane and complete.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let plan = FaultPlan::new().stall_on_step(2, 400);
    let srv = Coordinator::start(
        faulty_factory(&tok, 1, plan),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig::default(),
    );
    let mut a = request(1, "json", 64);
    a.constraint_prefix = "[".repeat(80);
    a.params.deadline_ms = Some(100);
    let b = request(2, "calc", 2);
    let rx_a = srv.submit(a);
    let rx_b = srv.submit(b);

    let resp_a = rx_a.recv().unwrap();
    assert_eq!(resp_a.finish, FinishReason::DeadlineExceeded);
    assert!(resp_a.tokens >= 1, "the cut keeps the partial output");
    assert!(resp_a.tokens < 64, "the deadline cut before the token budget");

    let resp_b = rx_b.recv().unwrap();
    assert!(resp_b.error.is_none(), "{:?}", resp_b.error);
    assert_ne!(resp_b.finish, FinishReason::Rejected, "B must get the freed lane");

    let snap = srv.snapshot();
    srv.shutdown();
    let i = SloClass::Interactive.index();
    assert_eq!(snap.classes[i].deadline_exceeded, 1);
    assert_eq!(snap.classes[i].deadline_shed_queued, 0);
}

#[test]
fn expired_queued_request_is_shed_and_capacity_goes_to_live_work() {
    // One lane. A stalls 400 ms on its first step while B (40 ms
    // deadline) and C wait in the queue: B's deadline expires *queued*,
    // so it must be shed at dequeue — zero tokens, no lane time — and C
    // still completes normally.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let plan = FaultPlan::new().stall_on_step(1, 400);
    let srv = Coordinator::start(
        faulty_factory(&tok, 1, plan),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig::default(),
    );
    let a = request(1, "json", 4);
    let mut b = request(2, "calc", 4);
    b.params.deadline_ms = Some(40);
    let c = request(3, "json", 4);
    let rx_a = srv.submit(a);
    let rx_b = srv.submit(b);
    let rx_c = srv.submit(c);

    let resp_a = rx_a.recv().unwrap();
    assert!(resp_a.error.is_none(), "{:?}", resp_a.error);
    let resp_b = rx_b.recv().unwrap();
    assert_eq!(resp_b.finish, FinishReason::DeadlineExceeded);
    assert_eq!(resp_b.tokens, 0, "a queued shed never touched a lane");
    let resp_c = rx_c.recv().unwrap();
    assert!(resp_c.error.is_none(), "{:?}", resp_c.error);

    let snap = srv.snapshot();
    srv.shutdown();
    let i = SloClass::Interactive.index();
    assert_eq!(snap.classes[i].deadline_shed_queued, 1);
    assert_eq!(snap.classes[i].deadline_exceeded, 0);
    // Sheds are accounted in their own family, not as lane finishes:
    // only A and C ever reached a lane.
    assert_eq!(snap.requests_finished, 2);
}

#[test]
fn dropped_stream_consumer_cancels_and_frees_the_lane() {
    // The harness-driven sink-disconnect fault: drop the stream's event
    // receiver after the first token. The replica observes the failed
    // send, finishes the lane Cancelled, and the lane is free for the
    // next request.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let srv = Coordinator::start(
        plain_factory(&tok, 1),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig::default(),
    );
    let mut a = request(1, "json", 64);
    a.constraint_prefix = "[".repeat(80);
    let stream = srv.submit_stream(a);
    // Wait for one committed token, then vanish mid-stream.
    match stream.events.recv().expect("first token") {
        TokenEvent::Token(_) => {}
        other => panic!("expected a token first, got {other:?}"),
    }
    let response = stream.response;
    drop(stream.events);
    let resp = response.recv().unwrap();
    assert_eq!(resp.finish, FinishReason::Cancelled);

    // The freed lane serves the next request.
    let follow = srv.generate(request(2, "calc", 2));
    assert!(follow.error.is_none(), "{:?}", follow.error);
    let snap = srv.snapshot();
    srv.shutdown();
    assert_eq!(snap.streams_cancelled, 1);
    assert_eq!(snap.requests_finished, 2);
}
