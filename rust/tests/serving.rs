//! Integration tests for the multi-replica serving coordinator:
//! multi-grammar routing under concurrent load, shutdown draining,
//! non-panicking submission, backpressure, and byte-identical parity
//! between the pooled (replicas × mask threads) and serial paths.

use std::collections::HashMap;
use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar, GrammarRegistry};
use syncode::coordinator::{
    Coordinator, CoordinatorConfig, FinishReason, GenParams, GenRequest, GenResponse, SloClass,
    Strategy, TokenEvent,
};
use syncode::runtime::{replicate_factory, LanguageModel, MockModel, ModelFactory};
use syncode::tokenizer::Tokenizer;

/// Mixed corpus so the mock model emits plausible bytes for both grammars.
fn docs() -> Vec<Vec<u8>> {
    vec![
        br#"{"name": "alice", "age": 30}"#.to_vec(),
        br#"{"items": [1, 2, 3], "ok": true}"#.to_vec(),
        br#"{"nested": {"a": null}}"#.to_vec(),
        b"1 + 2 * 3".to_vec(),
        b"math_sqrt(4) - 1".to_vec(),
        b"(7 - 2) / 5".to_vec(),
    ]
}

fn registry(tok: &Arc<Tokenizer>) -> Arc<GrammarRegistry> {
    let reg = Arc::new(GrammarRegistry::new());
    for g in ["json", "calc"] {
        let art = CompiledGrammar::compile(g, tok.clone(), &ArtifactConfig::default()).unwrap();
        reg.register(art).unwrap();
    }
    reg
}

fn factories(tok: &Arc<Tokenizer>, replicas: usize, lanes: usize) -> Vec<ModelFactory> {
    let tok = tok.clone();
    replicate_factory(replicas, move || {
        Ok(Box::new(MockModel::from_documents(tok.clone(), &docs(), lanes, 256, 11))
            as Box<dyn LanguageModel>)
    })
}

fn request_spec(id: u64, grammar: &str, max_new_tokens: usize, spec_k: usize) -> GenRequest {
    GenRequest {
        id,
        prompt: format!("produce {grammar} #{id}"),
        constraint_prefix: String::new(),
        grammar: Some(grammar.to_string()),
        params: GenParams {
            max_new_tokens,
            strategy: Strategy::TopP { temp: 0.85, p: 0.95 },
            seed: id * 13 + 7,
            opportunistic: id % 2 == 0,
            spec_k,
            ..Default::default()
        },
        token_sink: None,
    }
}

fn request(id: u64, grammar: &str, max_new_tokens: usize) -> GenRequest {
    request_spec(id, grammar, max_new_tokens, 0)
}

/// The shared validity rule (`CompiledGrammar::response_valid`): no
/// error, complete generations parse, truncated ones are valid prefixes.
fn assert_grammatical(reg: &GrammarRegistry, grammar: &str, resp: &GenResponse) {
    assert!(resp.error.is_none(), "req {}: {:?}", resp.id, resp.error);
    let art = reg.get(grammar).unwrap();
    assert!(
        art.response_valid(resp),
        "req {} emitted invalid {grammar} ({:?}): {:?}",
        resp.id,
        resp.finish,
        resp.text
    );
}

#[test]
fn pooled_coordinator_is_byte_identical_to_serial() {
    // The acceptance contract, squared: the replica/mask-pool pipeline
    // must produce exactly the outputs of the old serial step path for
    // identical seeds — and neither speculative decoding nor SLO-class
    // scheduling may change anything, at every spec_k, pooled or inline.
    // Classes reorder admission only, so mixing them into every config
    // (ids 0/3/6 ride the batch queue) must leave bytes untouched.
    // Baseline: serial, spec off.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);

    let mut baseline: Option<HashMap<u64, (String, usize)>> = None;
    for spec_k in [0usize, 2, 4] {
        for (replicas, mask_threads) in [(1usize, 0usize), (2, 2)] {
            let reqs: Vec<GenRequest> = (0..8)
                .map(|i| {
                    let mut r =
                        request_spec(i, if i % 2 == 0 { "json" } else { "calc" }, 48, spec_k);
                    r.params.slo =
                        if i % 3 == 0 { SloClass::Batch } else { SloClass::Interactive };
                    r
                })
                .collect();
            let srv = Coordinator::start(
                factories(&tok, replicas, 2),
                tok.clone(),
                reg.clone(),
                CoordinatorConfig { mask_threads, ..Default::default() },
            );
            let rxs: Vec<_> = reqs.iter().map(|r| srv.submit(r.clone())).collect();
            let mut out = HashMap::new();
            for rx in rxs {
                let resp = rx.recv().unwrap();
                assert!(resp.error.is_none(), "{:?}", resp.error);
                out.insert(resp.id, (resp.text, resp.tokens));
            }
            srv.shutdown();
            match &baseline {
                None => baseline = Some(out),
                Some(base) => assert_eq!(
                    base, &out,
                    "spec_k={spec_k} × ({replicas} replicas, {mask_threads} mask threads) \
                     diverged from the serial spec-off path"
                ),
            }
        }
    }
}

#[test]
fn lane_freed_mid_decode_admits_queued_request_before_long_lane_finishes() {
    // The continuous-batching acceptance test. One replica, two lanes:
    // A is pinned long (an 80-deep bracket prefix makes EOS unreachable,
    // so it runs to MaxTokens at exactly 64 chunks), B finishes within
    // 2 tokens, C waits in the queue. The moment B's lane frees, C must
    // be admitted and commit its (single) token while A is still
    // mid-generation. A and C share one token sink, and one scheduler
    // thread feeds it in commit order — so the proof is ordering on a
    // single channel, no cross-thread timing: the merged stream must
    // contain two index-0 chunks (A's first, then C's only one) and end
    // with A's index-63 chunk.
    //
    // And scheduling must never touch bytes: all three texts have to be
    // identical across spec_k {0,4} × {inline, pooled}.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);

    let mut baseline: Option<Vec<(u64, String)>> = None;
    for spec_k in [0usize, 4] {
        for mask_threads in [0usize, 2] {
            let srv = Coordinator::start(
                factories(&tok, 1, 2),
                tok.clone(),
                reg.clone(),
                CoordinatorConfig { mask_threads, ..Default::default() },
            );
            let (tx, events) = std::sync::mpsc::channel();
            let mut a = request_spec(1, "json", 64, spec_k);
            a.constraint_prefix = "[".repeat(80);
            a.token_sink = Some(tx.clone());
            let mut b = request_spec(2, "json", 2, spec_k);
            b.constraint_prefix = "[".repeat(80);
            let mut c = request_spec(3, "calc", 1, 0);
            c.token_sink = Some(tx);
            // Submission order fills both lanes (A, B) and queues C.
            let rxs = [srv.submit(a), srv.submit(b), srv.submit(c)];

            // Drain the shared stream until both sinks are dropped (their
            // lanes finished); Token events arrive in commit order.
            let mut chunks: Vec<usize> = Vec::new();
            let mut finished = 0usize;
            while let Ok(ev) = events.recv() {
                match ev {
                    TokenEvent::Token(t) => chunks.push(t.index),
                    TokenEvent::Finished { .. } => finished += 1,
                }
            }
            assert_eq!(finished, 2, "A and C must each terminate their stream");
            assert_eq!(chunks.len(), 65, "A commits exactly 64 tokens, C exactly 1");
            let zeros: Vec<usize> =
                chunks.iter().enumerate().filter(|(_, i)| **i == 0).map(|(p, _)| p).collect();
            assert_eq!(zeros.len(), 2, "two first-token commits on the shared sink");
            assert_eq!(
                *chunks.last().unwrap(),
                63,
                "C's token must land BEFORE A's final chunk — the freed lane \
                 was not refilled mid-decode (spec_k={spec_k}, \
                 mask_threads={mask_threads})"
            );

            let mut out: Vec<(u64, String)> = rxs
                .into_iter()
                .map(|rx| {
                    let resp = rx.recv().unwrap();
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    (resp.id, resp.text)
                })
                .collect();
            out.sort();
            srv.shutdown();
            match &baseline {
                None => baseline = Some(out),
                Some(base) => assert_eq!(
                    base, &out,
                    "continuous admission changed bytes at spec_k={spec_k}, \
                     mask_threads={mask_threads}"
                ),
            }
        }
    }
}

#[test]
fn multi_grammar_routing_under_concurrent_load() {
    // Several grammars through one registry, across 2 replicas and a
    // 2-thread mask pool, submitted from 3 concurrent client threads.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let srv = Coordinator::start(
        factories(&tok, 2, 2),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig { mask_threads: 2, ..Default::default() },
    );

    let per_thread = 6u64;
    let mut results: Vec<(u64, String, GenResponse)> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..3u64 {
            let srv = &srv;
            handles.push(s.spawn(move || {
                let mut got = Vec::new();
                for i in 0..per_thread {
                    let id = t * per_thread + i;
                    let grammar = if id % 2 == 0 { "json" } else { "calc" };
                    let resp = srv.generate(request(id, grammar, 40));
                    got.push((id, grammar.to_string(), resp));
                }
                got
            }));
        }
        for h in handles {
            results.extend(h.join().unwrap());
        }
    });

    assert_eq!(results.len(), 18);
    for (id, grammar, resp) in &results {
        assert_eq!(*id, resp.id);
        assert_grammatical(&reg, grammar, resp);
    }
    let snap = srv.snapshot();
    assert_eq!(snap.requests_finished, 18);
    // Per-replica metrics must add up to the global request count.
    let per_replica: u64 = srv.replica_snapshots().iter().map(|s| s.requests_finished).sum();
    assert_eq!(per_replica, 18);
    // The pool actually ran jobs and prewarmed masks during decode.
    assert!(snap.mask_pool_jobs > 0, "mask pool never ran");
    assert!(snap.masks_prewarmed > 0, "no prewarm overlap happened");
    srv.shutdown();
}

#[test]
fn shutdown_drains_inflight_and_queued_without_losing_responses() {
    // One replica with 2 lanes and 6 requests: 2 go in-flight, 4 queue.
    // close() immediately after submission — every request must still get
    // a real (non-rejected) response.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let srv = Coordinator::start(
        factories(&tok, 1, 2),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig { mask_threads: 2, ..Default::default() },
    );
    let rxs: Vec<_> = (0..6)
        .map(|i| srv.submit(request(i, if i % 2 == 0 { "json" } else { "calc" }, 32)))
        .collect();
    srv.close();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response channel closed without a response");
        assert_ne!(
            resp.finish,
            FinishReason::Rejected,
            "queued request {i} was dropped by shutdown"
        );
        assert!(resp.error.is_none(), "req {i}: {:?}", resp.error);
    }
    // After close, new submissions are rejected — without panicking.
    let late = srv.generate(request(99, "json", 8));
    assert_eq!(late.finish, FinishReason::Rejected);
    srv.shutdown();
}

#[test]
fn unknown_grammar_fails_request_not_server() {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let srv = Coordinator::start(
        factories(&tok, 2, 2),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig { mask_threads: 1, ..Default::default() },
    );
    let bad = srv.generate(request(1, "sql2", 8));
    assert_eq!(bad.finish, FinishReason::EngineError);
    assert!(bad.error.unwrap().contains("unknown grammar"));
    // The server keeps serving afterwards.
    let good = srv.generate(request(2, "json", 24));
    assert_grammatical(&reg, "json", &good);
    srv.shutdown();
}

#[test]
fn backpressure_bounded_queue_still_completes_everything() {
    // queue_cap = 2 forces submitters to block; the replicas drain the
    // queue concurrently so every request completes.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let srv = Coordinator::start(
        factories(&tok, 2, 2),
        tok.clone(),
        reg.clone(),
        CoordinatorConfig { mask_threads: 2, queue_cap: 2, ..Default::default() },
    );
    let n = 12u64;
    let mut done = 0usize;
    std::thread::scope(|s| {
        let (tx, rx) = std::sync::mpsc::channel();
        for t in 0..2u64 {
            let srv = &srv;
            let tx = tx.clone();
            s.spawn(move || {
                for i in 0..n / 2 {
                    let id = t * (n / 2) + i;
                    let g = if id % 2 == 0 { "json" } else { "calc" };
                    // submit blocks on the full queue (backpressure)
                    tx.send(srv.submit(request(id, g, 24))).unwrap();
                }
            });
        }
        drop(tx);
        while let Ok(resp_rx) = rx.recv() {
            let resp = resp_rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            done += 1;
        }
    });
    assert_eq!(done, n as usize);
    let snap = srv.snapshot();
    assert_eq!(snap.requests_finished, n);
    // The bounded queue was observed at depth ≥ 1 and never above cap.
    assert!(snap.queue_depth_max >= 1);
    assert!(snap.queue_depth_max <= 2, "queue exceeded its bound");
    srv.shutdown();
}

#[test]
fn grammar_rejected_drafts_never_reach_the_model() {
    use std::sync::atomic::{AtomicU64, Ordering};

    // Wraps the mock model and counts every draft position `decode_spec`
    // is asked to score — the model-side witness for the free-filter
    // contract: positions scored must equal drafts proposed minus drafts
    // the grammar rejected, i.e. a pruned draft never costs model work.
    struct SpyModel {
        inner: MockModel,
        scored: Arc<AtomicU64>,
    }
    impl LanguageModel for SpyModel {
        fn vocab_size(&self) -> usize {
            self.inner.vocab_size()
        }
        fn lanes(&self) -> usize {
            self.inner.lanes()
        }
        fn max_seq(&self) -> usize {
            self.inner.max_seq()
        }
        fn prefill(
            &mut self,
            lane: usize,
            tokens: &[u32],
        ) -> syncode::util::error::Result<Vec<f32>> {
            self.inner.prefill(lane, tokens)
        }
        fn decode(
            &mut self,
            last: &[Option<u32>],
        ) -> syncode::util::error::Result<Vec<Option<Vec<f32>>>> {
            self.inner.decode(last)
        }
        fn draft(&mut self, lane: usize, k: usize) -> Vec<u32> {
            self.inner.draft(lane, k)
        }
        fn decode_spec(
            &mut self,
            drafts: &[Option<Vec<u32>>],
        ) -> syncode::util::error::Result<Vec<Option<Vec<Vec<f32>>>>> {
            let positions: u64 = drafts.iter().flatten().map(|d| d.len() as u64).sum();
            self.scored.fetch_add(positions, Ordering::Relaxed);
            self.inner.decode_spec(drafts)
        }
        fn rollback(&mut self, lane: usize, n: usize) {
            self.inner.rollback(lane, n)
        }
        fn release(&mut self, lane: usize) {
            self.inner.release(lane)
        }
        fn name(&self) -> &'static str {
            "spy-mock"
        }
    }

    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let scored = Arc::new(AtomicU64::new(0));
    let scored_f = scored.clone();
    let tok_m = tok.clone();
    let factory: ModelFactory = Box::new(move || {
        Ok(Box::new(SpyModel {
            inner: MockModel::from_documents(tok_m.clone(), &docs(), 2, 256, 11),
            scored: scored_f.clone(),
        }) as Box<dyn LanguageModel>)
    });
    let srv =
        Coordinator::start(vec![factory], tok.clone(), reg.clone(), CoordinatorConfig::default());
    for i in 0..6 {
        let grammar = if i % 2 == 0 { "json" } else { "calc" };
        let resp = srv.generate(request_spec(i, grammar, 48, 4));
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let snap = srv.snapshot();
    srv.shutdown();
    assert!(snap.drafts_proposed > 0, "speculation never proposed a draft");
    // The zero-waste contract, counter-asserted end to end: every position
    // the model scored survived the grammar filter. (That the filter
    // actually rejects — and does so with zero extra DFA walks — is
    // pinned by maskpool's `pruning_performs_no_walks_beyond_the_plan`.)
    let scored = scored.load(Ordering::Relaxed);
    assert_eq!(
        snap.drafts_proposed - snap.drafts_grammar_rejected,
        scored,
        "a grammar-rejected draft reached decode_spec (or a surviving one didn't)"
    );
    assert!(snap.drafts_accepted <= scored, "accepted more drafts than were scored");
    assert!(snap.tokens_per_step_mean > 0.0);
}
