//! End-to-end tests for the request-time grammar surface: registering a
//! user-supplied grammar over `POST /v1/grammars` and generating against
//! it, duplicate-name replace-in-place while a generation is in flight
//! (old `Arc` survives, output byte-identical to a run without the
//! replacement), the hardened error matrix (400/413/422 as clean JSON,
//! never a panic or hang), DELETE semantics, and the
//! `syncode_grammar_*` metric families.
//!
//! Everything runs over real TCP sockets on ephemeral loopback ports,
//! the same path an external curl would take.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;
use syncode::artifact::{ArtifactConfig, CompiledGrammar, GrammarRegistry};
use syncode::coordinator::{Coordinator, CoordinatorConfig, GenResponse};
use syncode::net::http::fetch;
use syncode::net::json::finish_from_str;
use syncode::net::{HttpConfig, HttpServer};
use syncode::runtime::{replicate_factory, LanguageModel, MockModel};
use syncode::tokenizer::Tokenizer;
use syncode::util::json::{parse, Json};

fn docs() -> Vec<Vec<u8>> {
    vec![
        br#"{"name": "alice", "age": 30}"#.to_vec(),
        b"1 + 2 * 3".to_vec(),
        b"abba baab abab".to_vec(),
    ]
}

fn registry(tok: &Arc<Tokenizer>) -> Arc<GrammarRegistry> {
    let reg = Arc::new(GrammarRegistry::new());
    for g in ["json", "calc"] {
        let art = CompiledGrammar::compile(g, tok.clone(), &ArtifactConfig::default()).unwrap();
        reg.register(art).unwrap();
    }
    reg
}

/// Coordinator + HTTP front over the mock model, default grammar-API
/// config (real `CompileLimits`, no cache dir).
fn start_mock_http() -> (HttpServer, Arc<GrammarRegistry>, String) {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let tok_m = tok.clone();
    let factories = replicate_factory(1, move || {
        Ok(Box::new(MockModel::from_documents(tok_m.clone(), &docs(), 2, 256, 11))
            as Box<dyn LanguageModel>)
    });
    let cfg = CoordinatorConfig { mask_threads: 0, queue_cap: 64, ..Default::default() };
    let handle = Coordinator::start(factories, tok, reg.clone(), cfg);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        handle,
        reg.clone(),
        HttpConfig { workers: 6, ..Default::default() },
    )
    .expect("bind ephemeral port");
    let addr = server.local_addr().to_string();
    (server, reg, addr)
}

/// Encode a `POST /v1/grammars` body through the crate's own JSON
/// printer so newlines and quotes in the source are escaped correctly.
fn register_body(name: &str, lark_src: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("lark_src".to_string(), Json::Str(lark_src.to_string()));
    Json::Obj(m).to_string()
}

fn generate_body(grammar: &str, seed: u64, max_tokens: usize) -> String {
    format!(
        r#"{{"grammar": "{grammar}", "prompt": "produce {grammar} #{seed}",
           "max_tokens": {max_tokens}, "seed": {seed}, "strategy": "greedy"}}"#
    )
}

/// Rebuild a wire response into a `GenResponse` for the client-side
/// validity oracle.
fn wire_response(v: &Json) -> GenResponse {
    GenResponse {
        id: v.get("id").unwrap().as_usize().unwrap() as u64,
        text: v.get("text").unwrap().as_str().unwrap().to_string(),
        finish: finish_from_str(v.get("finish").unwrap().as_str().unwrap()).unwrap(),
        tokens: v.get("tokens").unwrap().as_usize().unwrap(),
        ttft_secs: 0.0,
        latency_secs: 0.0,
        error: None,
    }
}

const USER_SRC_AB: &str = "start: A+\nA: /[ab]/\n";
const USER_SRC_CD: &str = "start: B+\nB: /[cd]/\n";

#[test]
fn register_over_http_then_generate_against_it() {
    let (server, reg, addr) = start_mock_http();
    let a = addr.as_str();

    // Register a brand-new grammar over the wire.
    let (status, body) =
        fetch(a, "POST", "/v1/grammars", Some(&register_body("userdsl", USER_SRC_AB))).unwrap();
    assert_eq!(status, 200, "{body}");
    let v = parse(&body).expect("register response json");
    assert_eq!(v.get("name").unwrap().as_str(), Some("userdsl"));
    assert_eq!(v.get("replaced").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("from_cache").unwrap().as_bool(), Some(false));
    assert!(v.get("total_secs").unwrap().as_f64().unwrap() >= 0.0, "{body}");

    // It shows up in the registry detail listing with its source size.
    let (status, body) = fetch(a, "GET", "/v1/grammars", None).unwrap();
    assert_eq!(status, 200);
    let v = parse(&body).unwrap();
    let user = v
        .get("grammars")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|g| g.get("name").unwrap().as_str() == Some("userdsl"))
        .expect("registered grammar listed");
    assert_eq!(
        user.get("source_bytes").and_then(Json::as_usize),
        Some(USER_SRC_AB.len()),
        "{body}"
    );
    assert_eq!(user.get("from_cache").unwrap().as_bool(), Some(false));
    assert!(user.get("dfa_states").unwrap().as_usize().unwrap() > 0);

    // Generate against it: the output must be shaped by the new grammar
    // — and we don't take the server's word for it.
    let (status, body) = fetch(a, "POST", "/v1/generate", Some(&generate_body("userdsl", 7, 12)))
        .unwrap();
    assert_eq!(status, 200, "{body}");
    let v = parse(&body).unwrap();
    assert_eq!(v.get("grammar").unwrap().as_str(), Some("userdsl"));
    assert_eq!(v.get("valid").unwrap().as_bool(), Some(true), "{body}");
    let resp = wire_response(&v);
    assert!(!resp.text.is_empty(), "{body}");
    assert!(resp.text.bytes().all(|b| b == b'a' || b == b'b'), "{body}");
    assert!(reg.get("userdsl").unwrap().response_valid(&resp), "{body}");
    server.shutdown().shutdown();
}

#[test]
fn error_matrix_is_clean_4xx_json_and_server_survives() {
    let (server, reg, addr) = start_mock_http();
    let a = addr.as_str();
    let registered_before = reg.len();
    let post = |body: &str| fetch(a, "POST", "/v1/grammars", Some(body)).unwrap();

    // Every rejection must be the exact status class, carry a JSON
    // "error" body, and leave no partial registry entry behind.
    let expect = |status: u16, body: &str, label: &str| {
        let v = parse(body).unwrap_or_else(|e| panic!("{label}: not JSON ({e:?}): {body}"));
        assert!(v.get("error").is_some(), "{label}: no error field: {body}");
        status
    };

    // Wire/schema failures → 400.
    let (s, b) = post("not json");
    assert_eq!(expect(s, &b, "garbage"), 400);
    let (s, b) = post(r#"{"name": "g"}"#);
    assert_eq!(expect(s, &b, "missing lark_src"), 400);
    let (s, b) = post(r#"{"lark_src": "start: A\n"}"#);
    assert_eq!(expect(s, &b, "missing name"), 400);
    let (s, b) = post(r#"{"name": "g", "lark_src": "start: A\nA: \"a\"\n", "grammer": true}"#);
    assert_eq!(expect(s, &b, "unknown field"), 400);
    let (s, b) = post(r#"{"name": "../evil", "lark_src": "start: A\nA: \"a\"\n"}"#);
    assert_eq!(expect(s, &b, "path-traversal name"), 400);
    let (s, b) = post(r#"{"name": "g", "lark_src": 7}"#);
    assert_eq!(expect(s, &b, "non-string source"), 400);
    let (s, b) = post(r#"{"name": "g", "lark_src": ""}"#);
    assert_eq!(expect(s, &b, "empty source"), 400);

    // Oversize source → 413 (within the wire body cap, over the compile
    // limit, so this exercises `CompileLimits`, not the HTTP parser).
    let oversize = "a".repeat(300 * 1024);
    let (s, b) = post(&register_body("big", &oversize));
    assert_eq!(expect(s, &b, "oversize source"), 413, "{b}");

    // Unparseable lark → 422.
    let (s, b) = post(&register_body("broken", "start: %%% nope"));
    assert_eq!(expect(s, &b, "unparseable"), 422, "{b}");

    // Limit-exceeded (oversize regex body, within source cap) → 422.
    let big_regex = format!("start: A\nA: /{}/\n", "a".repeat(5000));
    let (s, b) = post(&register_body("bomb", &big_regex));
    assert_eq!(expect(s, &b, "regex over limit"), 422, "{b}");

    // No partial entries: nothing above may have registered.
    assert_eq!(reg.len(), registered_before, "partial registry entry leaked");
    for name in ["g", "big", "broken", "bomb"] {
        assert!(reg.get(name).is_none(), "{name} leaked into the registry");
    }

    // After all that abuse the server still serves — both endpoints.
    let (status, body) =
        fetch(a, "POST", "/v1/grammars", Some(&register_body("ok", USER_SRC_AB))).unwrap();
    assert_eq!(status, 200, "{body}");
    let (status, body) =
        fetch(a, "POST", "/v1/generate", Some(&generate_body("calc", 5, 12))).unwrap();
    assert_eq!(status, 200, "{body}");
    server.shutdown().shutdown();
}

#[test]
fn delete_unregisters_cleanly_and_generate_gets_clean_error() {
    let (server, reg, addr) = start_mock_http();
    let a = addr.as_str();

    let (status, _) =
        fetch(a, "POST", "/v1/grammars", Some(&register_body("tmpg", USER_SRC_AB))).unwrap();
    assert_eq!(status, 200);
    let (status, body) =
        fetch(a, "POST", "/v1/generate", Some(&generate_body("tmpg", 3, 8))).unwrap();
    assert_eq!(status, 200, "{body}");

    // DELETE removes it...
    let (status, body) = fetch(a, "DELETE", "/v1/grammars/tmpg", None).unwrap();
    assert_eq!(status, 200, "{body}");
    assert_eq!(parse(&body).unwrap().get("deleted").unwrap().as_str(), Some("tmpg"));
    assert!(reg.get("tmpg").is_none());

    // ...generating against it is now the generate endpoint's clean
    // unknown-grammar error (400, listing what is registered), not a
    // panic or a 500.
    let (status, body) =
        fetch(a, "POST", "/v1/generate", Some(&generate_body("tmpg", 4, 8))).unwrap();
    assert_eq!(status, 400, "{body}");
    let v = parse(&body).unwrap();
    let err = v.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("calc"), "error should list registered grammars: {body}");

    // Double-delete and deleting the never-registered → 404, JSON body.
    let (status, body) = fetch(a, "DELETE", "/v1/grammars/tmpg", None).unwrap();
    assert_eq!(status, 404, "{body}");
    assert!(parse(&body).unwrap().get("error").is_some());
    let (status, _) = fetch(a, "DELETE", "/v1/grammars/neverwas", None).unwrap();
    assert_eq!(status, 404);

    // Wrong methods on the grammar routes are 405s, not 404s.
    assert_eq!(fetch(a, "GET", "/v1/grammars/tmpg", None).unwrap().0, 405);
    assert_eq!(fetch(a, "PUT", "/v1/grammars", Some("{}")).unwrap().0, 405);

    // The listing no longer mentions it; the server still serves.
    let (_, body) = fetch(a, "GET", "/v1/grammars", None).unwrap();
    assert!(!body.contains("tmpg"), "{body}");
    let (status, _) = fetch(a, "POST", "/v1/generate", Some(&generate_body("json", 9, 8))).unwrap();
    assert_eq!(status, 200);
    server.shutdown().shutdown();
}

#[test]
fn grammar_metric_families_track_registrations() {
    let (server, _reg, addr) = start_mock_http();
    let a = addr.as_str();

    // One success, one failure.
    let (status, _) =
        fetch(a, "POST", "/v1/grammars", Some(&register_body("mdsl", USER_SRC_AB))).unwrap();
    assert_eq!(status, 200);
    let (status, _) =
        fetch(a, "POST", "/v1/grammars", Some(&register_body("mbad", "start: %%%"))).unwrap();
    assert_eq!(status, 422);

    // The registry stats are on the listing...
    let (_, body) = fetch(a, "GET", "/v1/grammars", None).unwrap();
    let v = parse(&body).unwrap();
    let stats = v.get("stats").expect("stats object");
    assert!(stats.get("compiles").unwrap().as_usize().unwrap() >= 1, "{body}");
    assert!(stats.get("compile_errors").unwrap().as_usize().unwrap() >= 1, "{body}");

    // ...and on /metrics, as parseable Prometheus families.
    let (status, text) = fetch(a, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    let mut families: BTreeMap<&str, f64> = BTreeMap::new();
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').expect("name value");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
        assert!(v.is_finite(), "{line}");
        families.insert(name, v);
    }
    assert!(families["syncode_grammar_compiles_total"] >= 1.0, "{text}");
    assert!(families["syncode_grammar_compile_errors_total"] >= 1.0, "{text}");
    assert_eq!(families["syncode_grammar_evictions_total"], 0.0, "{text}");
    assert!(families.contains_key("syncode_grammar_cache_hits_total"), "{text}");
    // json + calc + mdsl; the broken one must not be counted.
    assert_eq!(families["syncode_grammar_registered"], 3.0, "{text}");
    assert!(families["syncode_grammar_compile_seconds_count"] >= 1.0, "{text}");
    server.shutdown().shutdown();
}

// --------------------------------------------------------------------------
// Replace-in-place while a generation is in flight needs a model whose
// decode can be held open deterministically.

struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    fn release(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }
}

/// Uniform-logits model whose first decode signals `entered` and then
/// blocks until the gate opens; the grammar mask does all the shaping,
/// so output is deterministic per (grammar, seed).
struct StallModel {
    vocab: usize,
    gate: Arc<Gate>,
    entered: Option<Sender<()>>,
}

impl LanguageModel for StallModel {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn lanes(&self) -> usize {
        1
    }

    fn max_seq(&self) -> usize {
        256
    }

    fn prefill(&mut self, _lane: usize, _tokens: &[u32]) -> syncode::util::error::Result<Vec<f32>> {
        Ok(vec![0.0; self.vocab])
    }

    fn decode(
        &mut self,
        last: &[Option<u32>],
    ) -> syncode::util::error::Result<Vec<Option<Vec<f32>>>> {
        if let Some(tx) = self.entered.take() {
            let _ = tx.send(());
        }
        self.gate.wait();
        Ok(last.iter().map(|t| t.map(|_| vec![0.0; self.vocab])).collect())
    }

    fn release(&mut self, _lane: usize) {}

    fn name(&self) -> &'static str {
        "stall"
    }
}

fn start_stalled_http() -> (HttpServer, Arc<GrammarRegistry>, String, Arc<Gate>, Receiver<()>) {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry(&tok);
    let gate = Gate::new();
    let (etx, erx) = channel();
    let vocab = tok.vocab_size();
    let gate_m = gate.clone();
    let entered = Arc::new(Mutex::new(Some(etx)));
    let factories = replicate_factory(1, move || {
        Ok(Box::new(StallModel {
            vocab,
            gate: gate_m.clone(),
            entered: entered.lock().unwrap().take(),
        }) as Box<dyn LanguageModel>)
    });
    let cfg = CoordinatorConfig { mask_threads: 0, queue_cap: 16, ..Default::default() };
    let handle = Coordinator::start(factories, tok, reg.clone(), cfg);
    let server = HttpServer::bind(
        "127.0.0.1:0",
        handle,
        reg.clone(),
        HttpConfig { workers: 6, ..Default::default() },
    )
    .expect("bind");
    let addr = server.local_addr().to_string();
    (server, reg, addr, gate, erx)
}

/// Run one stalled-server lifecycle: register `userdsl`, start a
/// generation, wait until it is pinned inside decode, optionally
/// replace the grammar mid-flight, then release and collect the text.
fn stalled_generation(replace_mid_flight: bool) -> String {
    let (server, reg, addr, gate, entered) = start_stalled_http();
    let a = addr.to_string();
    let (status, body) =
        fetch(&a, "POST", "/v1/grammars", Some(&register_body("userdsl", USER_SRC_AB))).unwrap();
    assert_eq!(status, 200, "{body}");
    let art_old = reg.get("userdsl").unwrap();

    // A generation pinned in flight inside the model's first decode.
    let addr_t = a.clone();
    let t = std::thread::spawn(move || {
        fetch(&addr_t, "POST", "/v1/generate", Some(&generate_body("userdsl", 21, 4)))
            .expect("in-flight request")
    });
    entered.recv_timeout(Duration::from_secs(30)).expect("model never entered decode");

    if replace_mid_flight {
        // Replace with a grammar under which the in-flight output would
        // be INVALID — proving the generation is pinned to the old Arc.
        let (status, body) =
            fetch(&a, "POST", "/v1/grammars", Some(&register_body("userdsl", USER_SRC_CD)))
                .unwrap();
        assert_eq!(status, 200, "{body}");
        assert_eq!(parse(&body).unwrap().get("replaced").unwrap().as_bool(), Some(true));
        let art_new = reg.get("userdsl").unwrap();
        assert!(!Arc::ptr_eq(&art_old, &art_new), "must be replaced in place");
        // Replace-in-place never evicts, and the old Arc still answers.
        assert_eq!(reg.stats().evictions, 0);
        assert!(art_old.cx.prefix_valid(b"ab"));
    }

    gate.release();
    let (status, body) = t.join().expect("client thread");
    assert_eq!(status, 200, "{body}");
    let v = parse(&body).unwrap();
    assert_eq!(v.get("valid").unwrap().as_bool(), Some(true), "{body}");
    let resp = wire_response(&v);
    // The in-flight generation finished under the OLD grammar: all
    // a/b bytes (the replacement grammar only accepts c/d).
    assert!(!resp.text.is_empty(), "{body}");
    assert!(resp.text.bytes().all(|b| b == b'a' || b == b'b'), "{body}");
    assert!(art_old.response_valid(&resp), "{body}");
    server.shutdown().shutdown();
    resp.text
}

#[test]
fn replace_in_place_leaves_inflight_generation_byte_identical() {
    let baseline = stalled_generation(false);
    let replaced = stalled_generation(true);
    assert_eq!(
        baseline, replaced,
        "a mid-flight re-register must not perturb the pinned generation"
    );
}
