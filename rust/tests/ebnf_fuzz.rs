//! Dependency-free structure-aware fuzzing of the untrusted-grammar
//! input surface (`parse_ebnf_limited` under the default
//! [`CompileLimits`]).
//!
//! A seeded mutator (the crate's own xorshift [`Rng`] — fixed seed, so
//! every CI run explores the same inputs) splices, truncates,
//! byte-flips and chunk-duplicates a corpus built from the five shipped
//! `grammars/*.lark` files plus hand-written adversarial seeds in
//! `rust/tests/corpus/ebnf/` (deep nesting, huge repetitions,
//! alternation blow-ups, unterminated literals, multibyte soup).
//!
//! The only property asserted is the hardening contract: every input —
//! however mangled — must come back as `Ok(grammar)` or a clean
//! `GrammarError` within its time budget. No panic, no hang, no
//! unbounded allocation. `SYNCODE_FUZZ_ITERS` overrides the iteration
//! count (ci.sh's full tier raises it).

use std::time::{Duration, Instant};
use syncode::grammar::{parse_ebnf_limited, CompileLimits};
use syncode::util::rng::Rng;

/// One parse attempt must resolve well inside the compile budget
/// (default `budget_ms` is 10s; the slack covers debug-build CI).
const PER_CALL_BUDGET: Duration = Duration::from_secs(30);

fn corpus() -> Vec<(String, String)> {
    let mut seeds = Vec::new();
    for name in ["json", "calc", "sql", "python", "go"] {
        let path = format!("grammars/{name}.lark");
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {path}: {e}"));
        seeds.push((path, src));
    }
    let dir = "rust/tests/corpus/ebnf";
    let mut extra: Vec<_> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("read_dir {dir}: {e}"))
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("lark"))
        .collect();
    extra.sort();
    for path in extra {
        let src = std::fs::read_to_string(&path).expect("read corpus seed");
        seeds.push((path.display().to_string(), src));
    }
    assert!(seeds.len() >= 10, "corpus went missing: {} seeds", seeds.len());
    seeds
}

fn iterations() -> usize {
    match std::env::var("SYNCODE_FUZZ_ITERS") {
        Ok(v) => v.parse().unwrap_or_else(|_| panic!("bad SYNCODE_FUZZ_ITERS: {v}")),
        Err(_) => 300,
    }
}

/// One structure-aware mutation over the byte form of two seeds.
/// Mutants may be invalid UTF-8 at the byte level; they are lossily
/// re-coded because the parser's input type is `&str` (the HTTP surface
/// performs the same UTF-8 gate before the parser ever sees bytes).
fn mutate(rng: &mut Rng, a: &[u8], b: &[u8]) -> String {
    let mut bytes: Vec<u8> = match rng.below(4) {
        // Splice: prefix of one seed + suffix of another.
        0 => {
            let cut_a = rng.below(a.len() + 1);
            let cut_b = rng.below(b.len() + 1);
            let mut v = a[..cut_a].to_vec();
            v.extend_from_slice(&b[cut_b..]);
            v
        }
        // Truncate: random prefix (tests mid-token EOF everywhere).
        1 => a[..rng.below(a.len() + 1)].to_vec(),
        // Byte flips: scatter corruption without changing structure.
        2 => {
            let mut v = a.to_vec();
            if !v.is_empty() {
                for _ in 0..rng.range(1, 9) {
                    let i = rng.below(v.len());
                    v[i] ^= 1 << rng.below(8);
                }
            }
            v
        }
        // Chunk duplication: repeat a random slice (repetition bombs).
        _ => {
            let mut v = a.to_vec();
            if !v.is_empty() {
                let lo = rng.below(v.len());
                let hi = rng.range(lo, v.len());
                let chunk = v[lo..hi].to_vec();
                for _ in 0..rng.range(1, 5) {
                    v.extend_from_slice(&chunk);
                }
            }
            v
        }
    };
    // Keep mutants under the source cap most of the time so the deeper
    // parser stages actually run (oversize is covered by its own seed).
    bytes.truncate(128 * 1024);
    String::from_utf8_lossy(&bytes).into_owned()
}

/// The contract under test: error-or-success, in budget. Returns
/// whether the input was accepted.
fn parse_one(label: &str, src: &str, limits: &CompileLimits) -> bool {
    let t0 = Instant::now();
    let ok = parse_ebnf_limited(src, limits).is_ok();
    let dt = t0.elapsed();
    assert!(
        dt < PER_CALL_BUDGET,
        "{label}: parse took {dt:?} (> {PER_CALL_BUDGET:?}) on {} bytes",
        src.len()
    );
    ok
}

#[test]
fn raw_seeds_never_panic_and_shipped_grammars_parse() {
    let limits = CompileLimits::default();
    for (label, src) in corpus() {
        let ok = parse_one(&label, &src, &limits);
        // The five shipped grammars must parse under the default
        // hardening limits — otherwise real users hit the caps.
        if label.starts_with("grammars/") {
            assert!(ok, "shipped grammar rejected under default limits: {label}");
        }
    }
}

#[test]
fn mutated_corpus_is_error_or_success_never_panic() {
    let limits = CompileLimits::default();
    let seeds = corpus();
    let mut rng = Rng::new(0xEB2F_5EED);
    let iters = iterations();
    let (mut accepted, mut rejected) = (0usize, 0usize);
    for i in 0..iters {
        let a = &seeds[rng.below(seeds.len())];
        let b = &seeds[rng.below(seeds.len())];
        let src = mutate(&mut rng, a.1.as_bytes(), b.1.as_bytes());
        let label = format!("iter {i} ({} x {})", a.0, b.0);
        if parse_one(&label, &src, &limits) {
            accepted += 1;
        } else {
            rejected += 1;
        }
    }
    // Sanity on the mutator itself: it must produce both outcomes, or
    // it is not exploring the boundary where parser bugs live.
    assert!(rejected > 0, "mutator produced no invalid inputs in {iters} iters");
    assert!(
        accepted + rejected == iters,
        "accounting bug: {accepted}+{rejected} != {iters}"
    );
    eprintln!("[ebnf_fuzz] {iters} iterations: {accepted} accepted, {rejected} rejected");
}

#[test]
fn tight_limits_reject_instead_of_ooming() {
    // Under deliberately tiny caps, the shipped grammars themselves
    // become "hostile" inputs: every rejection must be a clean error.
    let tiny = CompileLimits {
        max_source_bytes: 512,
        max_rules: 4,
        max_terminals: 2,
        max_regex_bytes: 16,
        max_nfa_states: 32,
        max_dfa_states: 16,
        budget_ms: 1000,
    };
    let mut saw_rejection = false;
    for (label, src) in corpus() {
        saw_rejection |= !parse_one(&label, &src, &tiny);
    }
    assert!(saw_rejection, "tiny limits rejected nothing");
}
