//! Integration tests for the compiled-artifact layer: whole-artifact
//! round-trips, serial-vs-parallel build equivalence at artifact level,
//! and multi-grammar serving through a `GrammarRegistry` — several server
//! lanes decoding against *different* grammars in one batched loop.

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar, GrammarRegistry};
use syncode::coordinator::{FinishReason, GenParams, GenRequest, Server, Strategy};
use syncode::engine::baselines::StandardEngine;
use syncode::coordinator::EngineFactory;
use syncode::mask::MaskStoreConfig;
use syncode::runtime::{MockModel, ModelFactory};
use syncode::tokenizer::Tokenizer;
use syncode::util::rng::Rng;

fn mixed_docs() -> Vec<Vec<u8>> {
    vec![
        br#"{"name": "alice", "age": 30}"#.to_vec(),
        br#"{"items": [1, 2, 3], "ok": true}"#.to_vec(),
        b"math_sqrt(3) * (2.27) + 14".to_vec(),
        b"1 + 2 * (3 + 4)".to_vec(),
        br#"{"nested": {"a": null}}"#.to_vec(),
        b"math_sin(30) + math_cos(60)".to_vec(),
    ]
}

fn registry_json_calc(tok: &Arc<Tokenizer>) -> Arc<GrammarRegistry> {
    let reg = Arc::new(GrammarRegistry::new());
    for g in ["json", "calc"] {
        let art = CompiledGrammar::compile(g, tok.clone(), &ArtifactConfig::default())
            .unwrap_or_else(|e| panic!("{g}: {e}"));
        reg.register(art).unwrap();
    }
    reg
}

#[test]
fn registry_serves_two_grammars_in_one_batch() {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry_json_calc(&tok);
    let tok_m = tok.clone();
    let model: ModelFactory = Box::new(move || {
        Ok(Box::new(MockModel::from_documents(tok_m.clone(), &mixed_docs(), 2, 256, 11)))
    });
    let srv = Server::start(model, tok.clone(), reg.clone());

    // Interleave grammars so both occupy lanes of the same decode loop.
    let reqs: Vec<GenRequest> = (0..6u64)
        .map(|i| GenRequest {
            id: i,
            prompt: format!("request {i}"),
            constraint_prefix: String::new(),
            grammar: Some(if i % 2 == 0 { "json" } else { "calc" }.to_string()),
            params: GenParams {
                max_new_tokens: 80,
                strategy: Strategy::Temperature(0.8),
                seed: i * 13 + 1,
                opportunistic: i % 3 == 0,
                ..Default::default()
            },
            token_sink: None,
        })
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|r| srv.submit(r.clone())).collect();
    for (req, rx) in reqs.iter().zip(rxs) {
        let resp = rx.recv().unwrap();
        let gname = req.grammar.clone().unwrap();
        assert!(resp.error.is_none(), "{gname}: {:?}", resp.error);
        let art = reg.get(&gname).unwrap();
        if resp.finish == FinishReason::Eos {
            assert!(
                art.cx.check_complete(resp.text.as_bytes()).is_ok(),
                "{gname}: EOS output invalid: {:?}",
                resp.text
            );
        } else {
            assert!(
                art.cx.prefix_valid(resp.text.as_bytes()),
                "{gname}: invalid prefix: {:?}",
                resp.text
            );
        }
    }
    let snap = srv.snapshot();
    assert_eq!(snap.requests_finished, 6);
    srv.shutdown();
}

#[test]
fn unknown_grammar_fails_request_not_server() {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let reg = registry_json_calc(&tok);
    let tok_m = tok.clone();
    let model: ModelFactory = Box::new(move || {
        Ok(Box::new(MockModel::from_documents(tok_m.clone(), &mixed_docs(), 2, 256, 3)))
    });
    let srv = Server::start(model, tok.clone(), reg);
    let bad = srv.generate(GenRequest {
        id: 1,
        prompt: "x".into(),
        constraint_prefix: String::new(),
        grammar: Some("fortran".into()),
        params: GenParams::default(),
        token_sink: None,
    });
    assert_eq!(bad.finish, FinishReason::EngineError);
    assert!(bad.error.unwrap().contains("unknown grammar"));
    // The server stays healthy for routable requests afterwards.
    let ok = srv.generate(GenRequest {
        id: 2,
        prompt: "y".into(),
        constraint_prefix: String::new(),
        grammar: Some("calc".into()),
        params: GenParams { max_new_tokens: 30, ..GenParams::default() },
        token_sink: None,
    });
    assert!(ok.error.is_none(), "{:?}", ok.error);
    srv.shutdown();
}

#[test]
fn single_factory_rejects_grammar_routing() {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let tok_m = tok.clone();
    let model: ModelFactory = Box::new(move || {
        Ok(Box::new(MockModel::from_documents(tok_m.clone(), &mixed_docs(), 2, 256, 5)))
    });
    let factory: EngineFactory = Box::new(|| Box::new(StandardEngine::new()));
    let srv = Server::start(model, tok, factory);
    let resp = srv.generate(GenRequest {
        id: 1,
        prompt: "x".into(),
        constraint_prefix: String::new(),
        grammar: Some("json".into()),
        params: GenParams { max_new_tokens: 10, ..GenParams::default() },
        token_sink: None,
    });
    assert_eq!(resp.finish, FinishReason::EngineError);
    assert!(resp.error.unwrap().contains("single-grammar"));
    srv.shutdown();
}

#[test]
fn artifact_roundtrip_identical_masks_on_random_prefixes() {
    // Serialise → deserialise → byte-level mask agreement on random
    // prefixes, across a grammar with a post-lex pass (python) too.
    let mut rng = Rng::new(97);
    for gname in ["json", "python"] {
        let tok = Arc::new(Tokenizer::ascii_byte_level());
        let art = CompiledGrammar::compile(gname, tok, &ArtifactConfig::default())
            .unwrap_or_else(|e| panic!("{gname}: {e}"));
        let art2 = CompiledGrammar::from_bytes(&art.to_bytes())
            .unwrap_or_else(|e| panic!("{gname}: {e}"));
        use syncode::engine::ConstraintEngine as _;
        let mut e1 = art.engine();
        let mut e2 = art2.engine();
        for doc in syncode::eval::dataset::corpus(gname, 8, 29) {
            let cut = rng.below(doc.len() + 1);
            let prefix = String::from_utf8_lossy(&doc[..cut]).to_string();
            e1.reset(&prefix);
            e2.reset(&prefix);
            match (e1.compute_mask(), e2.compute_mask()) {
                (Ok(Some(a)), Ok(Some(b))) => {
                    assert_eq!(a, b, "{gname}: masks differ at {prefix:?}")
                }
                (a, b) => assert_eq!(
                    a.is_err(),
                    b.is_err(),
                    "{gname}: outcome differs at {prefix:?}"
                ),
            }
        }
    }
}

#[test]
fn mmap_loaded_artifact_serves_requests_across_threads() {
    // The zero-copy warm path end to end: compile → cache file → mapped
    // load (`from_file`) → registry → batched serving. The view-backed
    // MaskStore crosses replica/worker threads behind its Arc'd mapping,
    // and every response is grammatically valid.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let art = CompiledGrammar::compile("json", tok.clone(), &ArtifactConfig::default())
        .unwrap();
    let dir = std::env::temp_dir().join("syncode_mmap_serving_test");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("json.syncart");
    std::fs::write(&path, art.to_bytes()).unwrap();
    let mapped = CompiledGrammar::from_file(&path).unwrap();
    assert!(mapped.compile_stats.from_cache);
    #[cfg(all(unix, target_endian = "little"))]
    assert!(
        mapped.store.stats.zero_copy && mapped.store.stats.mapped,
        "unix warm load must be zero-copy from an mmap"
    );

    let reg = Arc::new(GrammarRegistry::new());
    reg.register(mapped.clone()).unwrap();
    let tok_m = tok.clone();
    let model: ModelFactory = Box::new(move || {
        Ok(Box::new(MockModel::from_documents(tok_m.clone(), &mixed_docs(), 2, 256, 23)))
    });
    let srv = Server::start(model, tok, reg.clone());
    let reqs: Vec<GenRequest> = (0..4u64)
        .map(|i| GenRequest {
            id: i,
            prompt: format!("request {i}"),
            constraint_prefix: String::new(),
            grammar: Some("json".to_string()),
            params: GenParams {
                max_new_tokens: 60,
                strategy: Strategy::Temperature(0.8),
                seed: i * 7 + 3,
                opportunistic: i % 2 == 0,
                ..Default::default()
            },
            token_sink: None,
        })
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|r| srv.submit(r.clone())).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(
            mapped.response_valid(&resp),
            "invalid response from mapped artifact: {:?} {:?}",
            resp.finish,
            resp.text
        );
    }
    srv.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_artifact_cache_is_a_clean_error_never_a_panic() {
    // Truncations and bit flips of a real `SYNCART1` cache file must
    // surface as clean `Err`s from the warm-load paths — a damaged cache
    // is an operational event (partial write, disk fault), not a crash.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let art = CompiledGrammar::compile("calc", tok.clone(), &ArtifactConfig::default())
        .unwrap();
    let blob = art.to_bytes();
    let dir = std::env::temp_dir().join(format!("syncode_corrupt_art_{}", std::process::id()));
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("calc.syncart");

    // Truncations at every stratum: mid-magic, mid-header, mid-store.
    for cut in [4usize, 40, blob.len() / 2, blob.len() - 9] {
        std::fs::write(&path, &blob[..cut]).unwrap();
        let res = CompiledGrammar::from_file(&path);
        assert!(res.is_err(), "truncation at {cut} must be a clean error");
    }
    // Bit flips in the header region (magic, length fields): the loader
    // must reject, never index out of bounds.
    for byte in [0usize, 9, 17, 33, 49] {
        let mut bad = blob.clone();
        bad[byte] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        // Either outcome is acceptable — a clean Corrupt/Mismatch error,
        // or (for flips in don't-care padding) a successful load — but
        // never a panic. Run it to find out.
        let _ = CompiledGrammar::from_file(&path);
    }

    // The serve-startup path heals instead of failing: a corrupt cache
    // under `load_or_compile` falls through to a clean recompile (miss),
    // and the rewritten cache warm-loads again.
    std::fs::write(&path, &blob[..blob.len() / 2]).unwrap();
    let cfg = ArtifactConfig::default();
    let (art2, hit) =
        CompiledGrammar::load_or_compile(&path, "calc", tok.clone(), &cfg).unwrap();
    assert!(!hit, "corrupt cache must be treated as a miss");
    assert_eq!(art2.to_bytes(), blob, "recompile reproduces the artifact");
    let (_, rehit) = CompiledGrammar::load_or_compile(&path, "calc", tok, &cfg).unwrap();
    assert!(rehit, "healed cache warm-loads");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cli_reports_artifact_failures_cleanly() {
    // End-to-end through the binary: an uncompilable grammar name exits
    // with code 1 and an `error:` line on stderr — not a panic backtrace.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_syncode"))
        .args(["compile", "--grammar", "nosuchgrammar", "--cache-dir"])
        .arg(std::env::temp_dir().join("syncode_cli_err_test"))
        .output()
        .expect("run syncode compile");
    assert_eq!(out.status.code(), Some(1), "clean exit code, not a crash");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error: compile nosuchgrammar"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "must not panic: {stderr}");
}

#[test]
fn parallel_artifact_equals_serial_artifact() {
    // Artifact-level restatement of the store property: a parallel-built
    // artifact serialises identically to a serially-built one.
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let serial_cfg = ArtifactConfig {
        mask: MaskStoreConfig::default(), // threads = 1
        ..ArtifactConfig::default()
    };
    let parallel_cfg = ArtifactConfig {
        mask: MaskStoreConfig { threads: 4, ..MaskStoreConfig::default() },
        ..ArtifactConfig::default()
    };
    let a = CompiledGrammar::compile("sql", tok.clone(), &serial_cfg).unwrap();
    let b = CompiledGrammar::compile("sql", tok, &parallel_cfg).unwrap();
    assert_eq!(a.to_bytes(), b.to_bytes());
}
