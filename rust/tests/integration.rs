//! Cross-module integration tests: full pipeline from grammar source to
//! constrained serving, engine-agreement properties across grammars, and
//! the PJRT artifact path (skipped gracefully when `make artifacts` has
//! not run).

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar};
use syncode::coordinator::{FinishReason, GenParams, GenRequest, Server, Strategy};
use syncode::engine::baselines::OutlinesLike;
use syncode::engine::ConstraintEngine;
use syncode::eval::harness::{EngineKind, EvalEnv};
use syncode::eval::{dataset, schema};
use syncode::runtime::{LanguageModel, PjrtModel, PjrtVariant};
use syncode::tokenizer::Tokenizer;
use syncode::util::rng::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("config.json").exists() && dir.join("decode.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("[skipping PJRT test: run `make artifacts` first]");
        None
    }
}

// ---------------------------------------------------------------- serving --

#[test]
fn constrained_serving_all_grammars() {
    // Every builtin grammar can drive the full mock-served pipeline and
    // EOS-finished generations satisfy the grammar's own compiler.
    for gname in ["json", "calc", "sql"] {
        let env = EvalEnv::new(gname, 60, 80, 23);
        let srv = Server::start(
            env.model_factory(),
            env.tok.clone(),
            env.engine_factory(EngineKind::Syncode),
        );
        for i in 0..3u64 {
            let resp = srv.generate(GenRequest {
                id: i,
                prompt: format!("produce {gname} #{i}"),
                constraint_prefix: String::new(),
                grammar: None,
                params: GenParams {
                    max_new_tokens: 90,
                    strategy: Strategy::Temperature(0.9),
                    seed: i * 7 + 1,
                    opportunistic: i % 2 == 0,
                    ..Default::default()
                },
                token_sink: None,
            });
            assert!(resp.error.is_none(), "{gname}: {:?}", resp.error);
            if resp.finish == FinishReason::Eos {
                assert!(
                    env.cx.check_complete(resp.text.as_bytes()).is_ok(),
                    "{gname}: EOS output invalid: {:?}",
                    resp.text
                );
            } else {
                assert!(
                    env.cx.prefix_valid(resp.text.as_bytes()),
                    "{gname}: invalid prefix: {:?}",
                    resp.text
                );
            }
        }
        srv.shutdown();
    }
}

#[test]
fn gpl_completion_prefix_invariant() {
    // Python/Go completions: prefix + generation always stays in L_p(G).
    for gname in ["python", "go"] {
        let env = EvalEnv::new(gname, 50, 80, 29);
        let tasks = match gname {
            "python" => dataset::python_tasks(2, 5),
            _ => dataset::go_tasks(2, 5),
        };
        let srv = Server::start(
            env.model_factory(),
            env.tok.clone(),
            env.engine_factory(EngineKind::Syncode),
        );
        for t in &tasks {
            let resp = srv.generate(GenRequest {
                id: t.id,
                prompt: t.prefix.clone(),
                constraint_prefix: t.prefix.clone(),
                grammar: None,
                params: GenParams {
                    max_new_tokens: 50,
                    strategy: Strategy::TopP { temp: 0.8, p: 0.9 },
                    seed: t.id,
                    opportunistic: true,
                    ..Default::default()
                },
                token_sink: None,
            });
            assert!(resp.error.is_none(), "{gname}: {:?}", resp.error);
            let full = format!("{}{}", t.prefix, resp.text);
            assert!(
                env.cx.prefix_valid(full.as_bytes()),
                "{gname}: generation left L_p(G): {full:?}"
            );
        }
        srv.shutdown();
    }
}

// ------------------------------------------------------ engine agreement --

#[test]
fn syncode_mask_superset_of_exact_across_grammars() {
    // Property test across random valid prefixes of several grammars:
    // SynCode's mask (store lookups) must contain the exact set computed
    // by the online validator — Theorem 1 soundness, empirically.
    let mut rng = Rng::new(41);
    for gname in ["json", "calc", "sql"] {
        let tok = Arc::new(Tokenizer::ascii_byte_level());
        let art = CompiledGrammar::compile(gname, tok.clone(), &ArtifactConfig::default())
            .unwrap_or_else(|e| panic!("{gname}: {e}"));
        let mut sync = art.engine();
        let mut outl = OutlinesLike::new(art.cx.clone(), tok.clone());
        for doc in dataset::corpus(gname, 8, 43) {
            let cut = rng.below(doc.len() + 1);
            let prefix = String::from_utf8_lossy(&doc[..cut]).to_string();
            sync.reset(&prefix);
            outl.reset(&prefix);
            let ms = match sync.compute_mask() {
                Ok(Some(m)) => m.clone(),
                _ => continue,
            };
            let mo = match outl.compute_mask() {
                Ok(Some(m)) => m.clone(),
                _ => continue,
            };
            assert!(
                mo.is_subset(&ms),
                "{gname}: unsound at prefix {prefix:?}"
            );
        }
    }
}

// ------------------------------------------------------------------ pjrt --

#[test]
fn pjrt_artifacts_roundtrip() {
    let Some(dir) = artifacts_dir() else { return };
    let tok = Arc::new(Tokenizer::from_file(&dir.join("tokenizer.json")).unwrap());
    let mut model = PjrtModel::load(&dir, PjrtVariant::KvCache).unwrap();
    assert_eq!(model.vocab_size(), tok.vocab_size());
    let prompt = tok.encode(b"Please generate a JSON object.");
    let mut ids = vec![tok.bos_id];
    ids.extend(prompt);
    let logits = model.prefill(0, &ids).unwrap();
    assert_eq!(logits.len(), tok.vocab_size());
    assert!(logits.iter().all(|x| x.is_finite()));
    // a couple of greedy decode steps
    let mut last = vec![None; model.lanes()];
    let first = argmax(&logits);
    last[0] = Some(first);
    let out = model.decode(&last).unwrap();
    assert!(out[0].is_some());
    model.release(0);
}

#[test]
fn pjrt_kv_matches_full_recompute() {
    // The §Perf before/after variants must agree on logits.
    let Some(dir) = artifacts_dir() else { return };
    let tok = Arc::new(Tokenizer::from_file(&dir.join("tokenizer.json")).unwrap());
    let mut kv = PjrtModel::load(&dir, PjrtVariant::KvCache).unwrap();
    let mut full = PjrtModel::load(&dir, PjrtVariant::FullRecompute).unwrap();
    let ids: Vec<u32> = {
        let mut v = vec![tok.bos_id];
        v.extend(tok.encode(b"{\"a\": 1"));
        v
    };
    let lk = kv.prefill(0, &ids).unwrap();
    let lf = full.prefill(0, &ids).unwrap();
    for (i, (a, b)) in lk.iter().zip(lf.iter()).enumerate() {
        assert!(
            (a - b).abs() < 2e-3 + 1e-3 * a.abs().max(b.abs()),
            "prefill logit {i}: {a} vs {b}"
        );
    }
    // one decode step each
    let t = argmax(&lk);
    let mut last = vec![None; kv.lanes()];
    last[0] = Some(t);
    let ok = kv.decode(&last).unwrap()[0].clone().unwrap();
    let of = full.decode(&last).unwrap()[0].clone().unwrap();
    for (i, (a, b)) in ok.iter().zip(of.iter()).enumerate() {
        assert!(
            (a - b).abs() < 2e-3 + 1e-3 * a.abs().max(b.abs()),
            "decode logit {i}: {a} vs {b}"
        );
    }
}

#[test]
fn pjrt_constrained_e2e_valid_json() {
    // The full three-layer path: AOT model + SynCode → valid JSON.
    let Some(dir) = artifacts_dir() else { return };
    let tok = Arc::new(Tokenizer::from_file(&dir.join("tokenizer.json")).unwrap());
    let art = CompiledGrammar::compile("json", tok.clone(), &ArtifactConfig::default())
        .expect("compile json");
    let cx = art.cx.clone();
    let dir2 = dir.clone();
    let srv = Server::start(
        Box::new(move || Ok(Box::new(PjrtModel::load(&dir2, PjrtVariant::KvCache)?))),
        tok.clone(),
        art.engine_factory(),
    );
    let tasks = dataset::json_mode_tasks(2, 3);
    for t in &tasks {
        let resp = srv.generate(GenRequest {
            id: t.id,
            prompt: t.prompt.clone(),
            constraint_prefix: String::new(),
            grammar: None,
            params: GenParams {
                max_new_tokens: 120,
                strategy: Strategy::TopP { temp: 0.7, p: 0.9 },
                seed: 5,
                opportunistic: true,
                ..Default::default()
            },
            token_sink: None,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        if resp.finish == FinishReason::Eos {
            let v = syncode::util::json::parse(resp.text.trim())
                .unwrap_or_else(|e| panic!("invalid JSON from PJRT path: {e}: {}", resp.text));
            let _ = schema::validate(&t.schema, &v); // schema validity is best-effort
        } else {
            assert!(cx.prefix_valid(resp.text.as_bytes()), "{:?}", resp.text);
        }
    }
    srv.shutdown();
}

#[test]
fn pjrt_reproduces_jax_greedy_sample() {
    // aot.py records a pure-JAX greedy continuation; the Rust PJRT path
    // must reproduce the same tokens — the strongest cross-language
    // numerics check we have.
    let Some(dir) = artifacts_dir() else { return };
    let sample_path = dir.join("sample.json");
    if !sample_path.exists() {
        eprintln!("[no sample.json — older artifacts]");
        return;
    }
    let sample = syncode::util::json::parse(
        &std::fs::read_to_string(&sample_path).unwrap(),
    )
    .unwrap();
    let prompt = sample.get("prompt").unwrap().as_str().unwrap().to_string();
    let want: Vec<u32> = sample
        .get("greedy_ids")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap() as u32)
        .collect();
    let tok = Arc::new(Tokenizer::from_file(&dir.join("tokenizer.json")).unwrap());
    let mut model = PjrtModel::load(&dir, PjrtVariant::KvCache).unwrap();
    let mut ids = vec![tok.bos_id];
    ids.extend(tok.encode(prompt.as_bytes()));
    let mut logits = model.prefill(0, &ids).unwrap();
    let mut got = Vec::new();
    for _ in 0..want.len() {
        let t = argmax(&logits);
        got.push(t);
        if t == tok.eos_id {
            break;
        }
        let mut last = vec![None; model.lanes()];
        last[0] = Some(t);
        logits = model.decode(&last).unwrap()[0].clone().unwrap();
    }
    assert_eq!(
        got,
        want,
        "rust: {:?} vs jax: {:?}",
        tok.decode_str(&got),
        sample.get("greedy_text").unwrap().as_str().unwrap()
    );
}

fn argmax(xs: &[f32]) -> u32 {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u32
}
