//! Trie-vs-reference mask-store parity (ISSUE 6 acceptance gate).
//!
//! The token-trie builder (`MaskStore::build`) must be **bit-identical**
//! to the retained naive builder (`MaskStore::build_reference`) — same
//! masks, same pool first-occurrence order, same SYNCMSK2 and SYNCMSK1
//! bytes — for every builtin grammar and at every thread count. A
//! faster-but-slightly-different store would silently change serving
//! behaviour, so equality is asserted on the serialised artifacts, not
//! on lookups.
//!
//! The step-reduction assertion at the bottom is the perf half of the
//! acceptance criteria: on json × a realistic BPE vocabulary the
//! prefix-sharing + dead-byte + byte-class filters must cut executed
//! `dfa.step` calls at least 10× below the naive Σ|items|·Σ|token bytes|
//! bound.

use syncode::eval::dataset;
use syncode::grammar::Grammar;
use syncode::mask::{MaskStore, MaskStoreConfig};
use syncode::tokenizer::Tokenizer;

const GRAMMARS: [&str; 5] = ["calc", "go", "json", "python", "sql"];

/// A modest shared BPE tokenizer trained on the union corpus of all five
/// grammars — every grammar sees the same (multi-byte) vocabulary, like
/// a multi-grammar registry would.
fn shared_tokenizer(merges: usize) -> Tokenizer {
    let docs: Vec<Vec<u8>> = GRAMMARS
        .iter()
        .flat_map(|g| dataset::corpus(g, 6, 0xC0FFEE))
        .collect();
    let flat: Vec<u8> = docs.iter().flat_map(|d| [d.as_slice(), b"\n"].concat()).collect();
    Tokenizer::train(&flat, merges)
}

#[test]
fn trie_matches_reference_all_grammars_threads_1_and_4() {
    let tok = shared_tokenizer(96);
    for name in GRAMMARS {
        let g = Grammar::builtin(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        let reference =
            MaskStore::build_reference(&g, &tok, MaskStoreConfig::default());
        let ref_v2 = reference.to_bytes();
        let ref_v1 = reference.to_bytes_v1();
        for threads in [1usize, 4] {
            let cfg = MaskStoreConfig { threads, ..MaskStoreConfig::default() };
            let trie = MaskStore::build(&g, &tok, cfg);
            assert_eq!(
                trie.to_bytes(),
                ref_v2,
                "{name} threads={threads}: SYNCMSK2 bytes diverge"
            );
            assert_eq!(
                trie.to_bytes_v1(),
                ref_v1,
                "{name} threads={threads}: SYNCMSK1 bytes diverge"
            );
        }
    }
}

#[test]
fn trie_cuts_json_walk_steps_at_least_10x() {
    // A larger vocabulary than the parity matrix: step reduction grows
    // with prefix density, and the acceptance bar is a 10× cut on a
    // realistically-sized mock vocab.
    let docs = dataset::corpus("json", 40, 7);
    let flat: Vec<u8> = docs.iter().flat_map(|d| [d.as_slice(), b"\n"].concat()).collect();
    let tok = Tokenizer::train(&flat, 512);
    let g = Grammar::builtin("json").unwrap();
    let s = MaskStore::build(&g, &tok, MaskStoreConfig::default());
    assert!(s.stats.walk_steps > 0, "trie build must count executed steps");
    assert!(
        s.stats.naive_steps >= 10 * s.stats.walk_steps,
        "expected ≥10× step reduction on json, got {}x ({} naive / {} executed)",
        s.stats.naive_steps / s.stats.walk_steps.max(1),
        s.stats.naive_steps,
        s.stats.walk_steps
    );
    assert!(s.stats.pruned_dead_byte > 0, "dead-byte pruning never fired");
    assert!(s.stats.trie_nodes_visited > 0);
}
