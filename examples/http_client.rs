//! **HTTP client driver**: exercise a running `syncode serve --http`
//! instance over real sockets using only the crate's own minimal client
//! (`net::http::fetch`) — no curl, no external dependencies.
//!
//! ```bash
//! # terminal 1
//! cargo run --release -- serve --http 127.0.0.1:8642 --grammars json,calc --mock
//! # terminal 2
//! cargo run --release --example http_client -- --addr 127.0.0.1:8642 --requests 8
//! cargo run --release --example http_client -- --addr 127.0.0.1:8642 --shutdown
//! ```
//!
//! Fires `--requests N` concurrent `POST /v1/generate` calls alternating
//! over the registered grammars, prints each verdict, then dumps
//! `/healthz` and a few `/metrics` lines. `--stream` instead sends
//! **one request per grammar over a single keep-alive connection** to
//! `POST /v1/generate?stream=1` and prints each token the moment its SSE
//! event arrives. `--shutdown` posts `/admin/shutdown` and exits.

use syncode::net::http::{fetch, HttpClient};
use syncode::util::cli::Args;
use syncode::util::json::{parse, Json};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let addr = args.get_or("addr", "127.0.0.1:8642");

    if args.flag("shutdown") {
        let (status, body) = fetch(addr.as_str(), "POST", "/admin/shutdown", Some("{}"))
            .expect("server unreachable");
        println!("shutdown -> {status} {body}");
        return;
    }

    if args.flag("stream") {
        stream_demo(&args, &addr);
        return;
    }

    // Which grammars does this server have?
    let (status, body) =
        fetch(addr.as_str(), "GET", "/v1/grammars", None).expect("server unreachable");
    assert_eq!(status, 200, "grammar listing failed: {body}");
    let listing = parse(&body).expect("grammar listing json");
    let grammars: Vec<String> = listing
        .get("grammars")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(|g| g.get("name").and_then(Json::as_str))
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    assert!(!grammars.is_empty(), "server has no grammars registered");
    println!("grammars: {}", grammars.join(", "));

    // Concurrent generation round-robined over the grammars.
    let n = args.get_num("requests", 8usize);
    let max_tokens = args.get_num("max-tokens", 60usize);
    let results: Vec<(u16, String)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let g = grammars[i % grammars.len()].clone();
                let addr = addr.clone();
                s.spawn(move || {
                    let body = format!(
                        r#"{{"grammar": "{g}", "prompt": "produce a valid {g} snippet (#{i})",
                            "max_tokens": {max_tokens}, "seed": {i}}}"#
                    );
                    fetch(addr.as_str(), "POST", "/v1/generate", Some(&body))
                        .expect("request failed")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    let mut invalid = 0usize;
    for (i, (status, body)) in results.iter().enumerate() {
        if *status != 200 {
            println!("req {i:2} -> {status} {body}");
            invalid += 1;
            continue;
        }
        let v = parse(body).expect("response json");
        let valid = v.get("valid").and_then(Json::as_bool).unwrap_or(false);
        invalid += !valid as usize;
        println!(
            "req {i:2} [{:8}] {:12} {:3} tokens valid={valid} | {}",
            v.get("grammar").and_then(Json::as_str).unwrap_or("?"),
            v.get("finish").and_then(Json::as_str).unwrap_or("?"),
            v.get("tokens").and_then(Json::as_f64).unwrap_or(0.0),
            v.get("text").and_then(Json::as_str).unwrap_or("").lines().next().unwrap_or(""),
        );
    }
    println!("\ninvalid or failed: {invalid}/{n}");

    let (_, health) = fetch(addr.as_str(), "GET", "/healthz", None).expect("healthz");
    println!("healthz: {health}");
    let (_, metrics) = fetch(addr.as_str(), "GET", "/metrics", None).expect("metrics");
    let interesting = ["syncode_requests_finished_total ", "syncode_tokens_per_second "];
    for line in metrics.lines() {
        if interesting.iter().any(|p| line.starts_with(p)) {
            println!("metrics: {line}");
        }
    }
}

/// Streaming consumer: one keep-alive connection, one SSE generation per
/// registered grammar, tokens printed as their events arrive.
fn stream_demo(args: &Args, addr: &str) {
    use std::io::Write as _;
    let max_tokens = args.get_num("max-tokens", 60usize);
    let mut client = HttpClient::connect(addr).expect("server unreachable");
    let (status, body) =
        client.request("GET", "/v1/grammars", None).expect("grammar listing");
    assert_eq!(status, 200, "grammar listing failed: {body}");
    let grammars: Vec<String> = parse(&body)
        .expect("grammar listing json")
        .get("grammars")
        .and_then(Json::as_arr)
        .map(|arr| {
            arr.iter()
                .filter_map(|g| g.get("name").and_then(Json::as_str))
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    assert!(!grammars.is_empty(), "server has no grammars registered");

    for (i, g) in grammars.iter().enumerate() {
        let body = format!(
            r#"{{"grammar": "{g}", "prompt": "produce a valid {g} snippet (#{i})",
                "max_tokens": {max_tokens}, "seed": {i}}}"#
        );
        let mut stream = client
            .request_stream("POST", "/v1/generate?stream=1", Some(&body))
            .expect("stream request");
        if stream.status() != 200 {
            let err = stream.into_body().unwrap_or_default();
            println!("[{g}] stream refused: {err}");
            continue;
        }
        print!("[{g}] ");
        let mut tokens = 0usize;
        while let Some((event, data)) = stream.next_event().expect("sse event") {
            match event.as_str() {
                "token" => {
                    tokens += 1;
                    let text = parse(&data)
                        .ok()
                        .and_then(|v| v.get("text").and_then(Json::as_str).map(str::to_string))
                        .unwrap_or_default();
                    print!("{text}");
                    let _ = std::io::stdout().flush();
                }
                "done" => {
                    let v = parse(&data).expect("done event json");
                    println!(
                        "\n[{g}] {} after {tokens} tokens, valid={}",
                        v.get("finish").and_then(Json::as_str).unwrap_or("?"),
                        v.get("valid").and_then(Json::as_bool).unwrap_or(false),
                    );
                }
                other => println!("\n[{g}] unexpected event: {other}"),
            }
        }
    }
}
