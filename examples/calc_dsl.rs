//! The paper's §3 illustrative walkthrough on the calculator DSL: shows
//! the remainder, the accept sequences A, the mask contents at each step,
//! and finishes with the Figure-4 question answered end-to-end.
//!
//! ```bash
//! cargo run --release --example calc_dsl
//! ```

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar};
use syncode::engine::ConstraintEngine;
use syncode::eval::exec::eval_calc;
use syncode::lexer::Lexer;
use syncode::tokenizer::Tokenizer;

fn main() {
    let tok = Arc::new(Tokenizer::ascii_byte_level());
    let art = CompiledGrammar::compile("calc", tok.clone(), &ArtifactConfig::default())
        .expect("compile calc");
    let cx = art.cx.clone();
    let mut eng = art.engine();

    // §3.2: C_k = "math_sqrt(3) * (2" — remainder r = "2", accept
    // sequences include {int, add}, {int, rpar}, {float}.
    let ck = "math_sqrt(3) * (2";
    let lexer = Lexer::new(&cx.grammar);
    let lr = lexer.lex(ck.as_bytes());
    println!("C_k = {ck:?}");
    println!(
        "fixed tokens: {:?}",
        lr.tokens
            .iter()
            .map(|t| cx.grammar.terminals[t.term as usize].name.as_str())
            .collect::<Vec<_>>()
    );
    println!(
        "remainder r = {:?} (complete: {})",
        String::from_utf8_lossy(lr.remainder(ck.as_bytes())),
        lr.remainder_term.is_some()
    );

    eng.reset(ck);
    let seqs = eng.accept_sequences().unwrap();
    println!("\naccept sequences A ({}):", seqs.len());
    for s in seqs {
        let names: Vec<&str> =
            s.iter().map(|&t| cx.grammar.terminals[t as usize].name.as_str()).collect();
        println!("  {{{}}}", names.join(", "));
    }

    let mask = eng.compute_mask().unwrap().unwrap();
    let allowed: Vec<String> = mask
        .iter_ones()
        .filter(|&i| !tok.is_special(i as u32))
        .take(20)
        .map(|i| format!("{:?}", (i as u8) as char))
        .collect();
    println!("\nfirst allowed next bytes: {}", allowed.join(" "));
    assert!(mask.get(b'.' as usize), "paper: '.' extends 2 toward a float");
    assert!(mask.get(b')' as usize), "paper: ')' closes the paren");
    assert!(!mask.get(b'x' as usize));

    // The paper's running answer, checked semantically.
    let answer = "math_sqrt(3) / 4 * (2.27) * (2.27)";
    let v = eval_calc(&cx.grammar, &cx.table, answer.as_bytes()).unwrap();
    println!("\nFigure-4 answer {answer} = {v:.4} (expected ≈ 2.2312)");
}
