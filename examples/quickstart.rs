//! Quickstart: the five-line path from a grammar to constrained serving.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the deterministic mock LM so it runs without artifacts; see
//! `examples/json_server.rs` for the PJRT end-to-end driver.

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar};
use syncode::coordinator::{GenParams, GenRequest, Server, Strategy};
use syncode::eval::dataset;
use syncode::runtime::MockModel;
use syncode::tokenizer::Tokenizer;

fn main() {
    // 1. Vocabulary: BPE over a grammar-sampled corpus.
    let docs = dataset::corpus("json", 80, 7);
    let flat: Vec<u8> = docs.iter().flat_map(|d| [d.as_slice(), b"\n"].concat()).collect();
    let tok = Arc::new(Tokenizer::train(&flat, 150));

    // 2. Compile the artifact: grammar → LR tables + DFA mask store, all
    //    offline work behind one Arc (parallel build by default).
    let art = CompiledGrammar::compile("json", tok.clone(), &ArtifactConfig::default())
        .expect("compile json");
    let s = &art.store.stats;
    println!(
        "artifact: {} states × {} terminals, {} unique masks, {:.1} MB, \
         built in {:.2}s on {} threads",
        s.num_dfa_states,
        s.num_terminals,
        s.unique_masks,
        s.mem_bytes as f64 / 1e6,
        s.build_secs,
        s.build_threads
    );

    // 3. Serve: model + per-request SynCode engines from the artifact.
    let tok_m = tok.clone();
    let srv = Server::start(
        Box::new(move || Ok(Box::new(MockModel::from_documents(tok_m.clone(), &docs, 2, 384, 11)))),
        tok.clone(),
        art.engine_factory(),
    );

    // 4. Generate.
    let resp = srv.generate(GenRequest {
        id: 1,
        prompt: "Please produce a JSON object describing a person.".into(),
        constraint_prefix: String::new(),
        grammar: None,
        params: GenParams {
            max_new_tokens: 120,
            strategy: Strategy::Temperature(0.8),
            seed: 42,
            opportunistic: true,
            ..Default::default()
        },
        token_sink: None,
    })
    .expect_served("quickstart example");
    println!("\ngenerated ({:?}, {} tokens):\n{}", resp.finish, resp.tokens, resp.text);

    // 5. It is valid JSON by construction.
    let parsed = syncode::util::json::parse(&resp.text);
    println!("\nvalid JSON: {}", parsed.is_ok());
    srv.shutdown();
}
