//! Quickstart: the five-line path from a grammar to constrained serving.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the deterministic mock LM so it runs without artifacts; see
//! `examples/json_server.rs` for the PJRT end-to-end driver.

use std::sync::Arc;
use syncode::coordinator::{GenParams, GenRequest, Server, Strategy};
use syncode::engine::{GrammarContext, SyncodeEngine};
use syncode::eval::dataset;
use syncode::mask::{MaskStore, MaskStoreConfig};
use syncode::parser::LrMode;
use syncode::runtime::MockModel;
use syncode::tokenizer::Tokenizer;

fn main() {
    // 1. Grammar → LR tables → post-lex pass.
    let cx = Arc::new(GrammarContext::builtin("json", LrMode::Lalr).unwrap());

    // 2. Vocabulary (BPE over a grammar-sampled corpus) + DFA mask store.
    let docs = dataset::corpus("json", 80, 7);
    let flat: Vec<u8> = docs.iter().flat_map(|d| [d.as_slice(), b"\n"].concat()).collect();
    let tok = Arc::new(Tokenizer::train(&flat, 150));
    let store = Arc::new(MaskStore::build(&cx.grammar, &tok, MaskStoreConfig::default()));
    println!(
        "mask store: {} states × {} terminals, {} unique masks, {:.1} MB, built in {:.2}s",
        store.stats.num_dfa_states,
        store.stats.num_terminals,
        store.stats.unique_masks,
        store.stats.mem_bytes as f64 / 1e6,
        store.stats.build_secs
    );

    // 3. Serve: model + per-request SynCode engines.
    let tok_m = tok.clone();
    let srv = Server::start(
        Box::new(move || Ok(Box::new(MockModel::from_documents(tok_m, &docs, 2, 384, 11)))),
        tok.clone(),
        Box::new(move || Box::new(SyncodeEngine::new(cx.clone(), store.clone(), tok.clone()))),
    );

    // 4. Generate.
    let resp = srv.generate(GenRequest {
        id: 1,
        prompt: "Please produce a JSON object describing a person.".into(),
        constraint_prefix: String::new(),
        params: GenParams {
            max_new_tokens: 120,
            strategy: Strategy::Temperature(0.8),
            seed: 42,
            opportunistic: true,
        },
    });
    println!("\ngenerated ({:?}, {} tokens):\n{}", resp.finish, resp.tokens, resp.text);

    // 5. It is valid JSON by construction.
    let parsed = syncode::util::json::parse(&resp.text);
    println!("\nvalid JSON: {}", parsed.is_ok());
    srv.shutdown();
}
