//! **End-to-end driver** (EXPERIMENTS.md §E2E): load the AOT-trained JAX
//! transformer through PJRT, serve batched JSON-mode requests with
//! SynCode constraints, and report latency/throughput + validity — the
//! proof that all three layers compose with Python off the request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example json_server
//! ```
//!
//! Flags: `--requests N` (default 12), `--mock` (bigram LM instead of
//! PJRT), `--full-recompute` (the §Perf "before" L2 variant),
//! `--unconstrained` (Standard engine for comparison).

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar};
use syncode::coordinator::{EngineFactory, GenParams, GenRequest, Server, Strategy};
use syncode::engine::baselines::StandardEngine;
use syncode::eval::{dataset, schema};
use syncode::runtime::{MockModel, ModelFactory, PjrtModel, PjrtVariant};
use syncode::tokenizer::Tokenizer;
use syncode::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_num("requests", 12usize);
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));

    // --- model + tokenizer --------------------------------------------------
    let use_mock = args.flag("mock") || !dir.join("config.json").exists();
    let (model, tok): (ModelFactory, Arc<Tokenizer>) = if use_mock {
        eprintln!("[mock model — run `make artifacts` for the PJRT path]");
        // Same recipe as `syncode compile/serve --grammars json` (corpus
        // 120 docs seed 7, 160 merges).
        let docs = dataset::corpus("json", 120, 7);
        let tok = Arc::new(Tokenizer::train(
            &docs.iter().flat_map(|d| [d.as_slice(), b"\n"].concat()).collect::<Vec<u8>>(),
            160,
        ));
        let tok_m = tok.clone();
        (
            Box::new(move || Ok(Box::new(MockModel::from_documents(tok_m, &docs, 2, 384, 3)))),
            tok,
        )
    } else {
        let tok =
            Arc::new(Tokenizer::from_file(&dir.join("tokenizer.json")).expect("tokenizer"));
        let variant = if args.flag("full-recompute") {
            PjrtVariant::FullRecompute
        } else {
            PjrtVariant::KvCache
        };
        println!("loading PJRT model from {} ({variant:?})", dir.display());
        (Box::new(move || Ok(Box::new(PjrtModel::load(&dir, variant)?))), tok)
    };

    // --- engine -------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let factory: EngineFactory = if args.flag("unconstrained") {
        Box::new(|| Box::new(StandardEngine::new()))
    } else {
        // Compile the grammar artifact (or warm-load the cache written by
        // a previous run of this example — the CLI's caches use
        // tokenizer-fingerprinted names, so this fixed name is private).
        let cache = std::path::PathBuf::from(
            args.get_or("grammar-cache", "artifacts/grammar-cache"),
        )
        .join("json-example.syncart");
        let (art, warm) = CompiledGrammar::load_or_compile(
            &cache,
            "json",
            tok.clone(),
            &ArtifactConfig::default(),
        )
        .expect("compile json artifact");
        println!(
            "artifact {} in {:.2}s ({} unique masks, {:.2} MB)",
            if warm { "warm-loaded" } else { "compiled" },
            art.compile_stats.total_secs,
            art.store.stats.unique_masks,
            art.store.stats.mem_bytes as f64 / 1e6
        );
        art.engine_factory()
    };
    println!("setup: {:.2}s", t0.elapsed().as_secs_f64());

    // --- serve a batch of requests -------------------------------------------
    let srv = Server::start(model, tok, factory);
    let tasks = dataset::json_mode_tasks(n, 3);
    let params = GenParams {
        max_new_tokens: args.get_num("max-tokens", 110),
        strategy: Strategy::TopP { temp: 0.8, p: 0.95 },
        seed: 5,
        opportunistic: true,
    };
    let t_subm = std::time::Instant::now();
    let rxs: Vec<_> = tasks
        .iter()
        .map(|t| {
            srv.submit(GenRequest {
                id: t.id,
                prompt: t.prompt.clone(),
                constraint_prefix: String::new(),
                grammar: None,
                params: params.clone(),
            })
        })
        .collect();
    let mut valid_json = 0;
    let mut valid_schema = 0;
    for (t, rx) in tasks.iter().zip(rxs) {
        let r = rx.recv().unwrap();
        let parsed = syncode::util::json::parse(r.text.trim());
        let sv = parsed
            .as_ref()
            .map(|v| schema::validate(&t.schema, v).is_empty())
            .unwrap_or(false);
        valid_json += parsed.is_ok() as usize;
        valid_schema += sv as usize;
        println!(
            "req {:2}: {:?} {:3} tok {:6.2}s ttft={:5.3}s json={} schema={} | {}",
            t.id,
            r.finish,
            r.tokens,
            r.latency_secs,
            r.ttft_secs,
            parsed.is_ok(),
            sv,
            truncate(&r.text, 60)
        );
    }
    let wall = t_subm.elapsed().as_secs_f64();
    let snap = srv.metrics.lock().unwrap().snapshot();
    println!("\n=== e2e summary ===");
    println!("{}", snap.report());
    println!(
        "wall={:.2}s  valid JSON {}/{}  schema-valid {}/{}",
        wall, valid_json, n, valid_schema, n
    );
    srv.shutdown();
}

fn truncate(s: &str, n: usize) -> String {
    let one_line: String = s.chars().map(|c| if c == '\n' { ' ' } else { c }).collect();
    if one_line.len() > n {
        format!("{}…", &one_line[..n])
    } else {
        one_line
    }
}
