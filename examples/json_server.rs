//! **End-to-end driver**: load the AOT-trained JAX transformer through
//! PJRT (or the mock bigram LM), serve batched JSON-mode requests with
//! SynCode constraints through the multi-replica coordinator, and report
//! latency/throughput + validity — the proof that all layers compose with
//! Python off the request path.
//!
//! ```bash
//! make artifacts && cargo run --release --example json_server
//! cargo run --release --example json_server -- --mock --replicas 2 --mask-threads 2
//! ```
//!
//! Flags: `--requests N` (default 12), `--mock` (bigram LM instead of
//! PJRT), `--full-recompute` (the §Perf "before" L2 variant),
//! `--unconstrained` (Standard engine for comparison), `--replicas N`
//! (model replicas behind one admission queue), `--mask-threads M`
//! (shared mask worker pool; 0 = inline mask computation), `--spec-k N`
//! (speculative draft length per step; 0 = off; output is byte-identical
//! at any value).

use std::sync::Arc;
use syncode::artifact::{ArtifactConfig, CompiledGrammar};
use syncode::coordinator::{
    Coordinator, CoordinatorConfig, EngineFactory, GenParams, GenRequest, GenResponse, Strategy,
};
use syncode::engine::baselines::StandardEngine;
use syncode::eval::{dataset, schema};
use syncode::runtime::{
    replicate_factory, LanguageModel, MockModel, ModelFactory, PjrtModel, PjrtVariant,
};
use syncode::tokenizer::Tokenizer;
use syncode::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_num("requests", 12usize);
    let replicas = args.get_num("replicas", 1usize).max(1);
    let mask_threads = args.get_num("mask-threads", 0usize);
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));

    // --- model + tokenizer --------------------------------------------------
    let use_mock = args.flag("mock") || !dir.join("config.json").exists();
    let (models, tok): (Vec<ModelFactory>, Arc<Tokenizer>) = if use_mock {
        eprintln!("[mock model — run `make artifacts` for the PJRT path]");
        // The shared mock recipe (`dataset::mock_serving_recipe`), same
        // defaults as `syncode compile/serve --grammars json`, so caches
        // warm-load across the CLI and this example.
        let (tok, docs) = dataset::mock_serving_recipe(&["json"], 120, 7, 160);
        let tok = Arc::new(tok);
        let tok_m = tok.clone();
        let models = replicate_factory(replicas, move || {
            Ok(Box::new(MockModel::from_documents(tok_m.clone(), &docs, 2, 384, 3))
                as Box<dyn LanguageModel>)
        });
        (models, tok)
    } else {
        let tok =
            Arc::new(Tokenizer::from_file(&dir.join("tokenizer.json")).expect("tokenizer"));
        let variant = if args.flag("full-recompute") {
            PjrtVariant::FullRecompute
        } else {
            PjrtVariant::KvCache
        };
        println!("loading PJRT model from {} ({variant:?})", dir.display());
        let dir_m = dir.clone();
        let models = replicate_factory(replicas, move || {
            Ok(Box::new(PjrtModel::load(&dir_m, variant)?) as Box<dyn LanguageModel>)
        });
        (models, tok)
    };

    // --- engine -------------------------------------------------------------
    let t0 = std::time::Instant::now();
    let factory: EngineFactory = if args.flag("unconstrained") {
        Box::new(|| Box::new(StandardEngine::new()))
    } else {
        // Compile the grammar artifact (or warm-load the cache written by
        // a previous run of this example — the CLI's caches use
        // tokenizer-fingerprinted names, so this fixed name is private).
        let cache = std::path::PathBuf::from(
            args.get_or("grammar-cache", "artifacts/grammar-cache"),
        )
        .join("json-example.syncart");
        let (art, warm) = CompiledGrammar::load_or_compile(
            &cache,
            "json",
            tok.clone(),
            &ArtifactConfig::default(),
        )
        .expect("compile json artifact");
        println!(
            "artifact {} in {:.2}s ({} unique masks, {:.2} MB)",
            if warm { "warm-loaded" } else { "compiled" },
            art.compile_stats.total_secs,
            art.store.stats.unique_masks,
            art.store.stats.mem_bytes as f64 / 1e6
        );
        art.engine_factory()
    };
    println!("setup: {:.2}s", t0.elapsed().as_secs_f64());

    // --- serve a batch of requests -------------------------------------------
    let spec_k = args.get_num("spec-k", 0usize);
    println!(
        "[coordinator: {replicas} replica(s), {mask_threads} mask thread(s), spec_k={spec_k}]"
    );
    let cfg = CoordinatorConfig { mask_threads, ..CoordinatorConfig::default() };
    let srv = Coordinator::start(models, tok, factory, cfg);
    let tasks = dataset::json_mode_tasks(n, 3);
    let params = GenParams {
        max_new_tokens: args.get_num("max-tokens", 110),
        strategy: Strategy::TopP { temp: 0.8, p: 0.95 },
        seed: 5,
        opportunistic: true,
        spec_k,
        ..Default::default()
    };
    let t_subm = std::time::Instant::now();
    let rxs: Vec<_> = tasks
        .iter()
        .map(|t| {
            srv.submit(GenRequest {
                id: t.id,
                prompt: t.prompt.clone(),
                constraint_prefix: String::new(),
                grammar: None,
                params: params.clone(),
                token_sink: None,
            })
        })
        .collect();
    let mut valid_json = 0;
    let mut valid_schema = 0;
    for (t, rx) in tasks.iter().zip(rxs) {
        let r = rx.recv().unwrap_or_else(|_| GenResponse::rejected(t.id, "no response"));
        let parsed = syncode::util::json::parse(r.text.trim());
        let sv = parsed
            .as_ref()
            .map(|v| schema::validate(&t.schema, v).is_empty())
            .unwrap_or(false);
        valid_json += parsed.is_ok() as usize;
        valid_schema += sv as usize;
        println!(
            "req {:2}: {:?} {:3} tok {:6.2}s ttft={:5.3}s json={} schema={} | {}",
            t.id,
            r.finish,
            r.tokens,
            r.latency_secs,
            r.ttft_secs,
            parsed.is_ok(),
            sv,
            truncate(&r.text, 60)
        );
    }
    let wall = t_subm.elapsed().as_secs_f64();
    println!("\n=== e2e summary ===");
    if replicas > 1 {
        for (i, snap) in srv.replica_snapshots().iter().enumerate() {
            println!("replica {i}: {}", snap.report());
        }
    }
    println!("global: {}", srv.snapshot().report());
    println!(
        "wall={:.2}s  valid JSON {}/{}  schema-valid {}/{}",
        wall, valid_json, n, valid_schema, n
    );
    srv.shutdown();
}

fn truncate(s: &str, n: usize) -> String {
    let one_line: String = s.chars().map(|c| if c == '\n' { ' ' } else { c }).collect();
    if one_line.len() > n {
        format!("{}…", &one_line[..n])
    } else {
        one_line
    }
}
