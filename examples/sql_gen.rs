//! Text-2-SQL demo (the Table 2 workload at demo scale): serve Spider-like
//! questions with and without SynCode, execute both outputs on the
//! in-memory database, and compare.
//!
//! ```bash
//! cargo run --release --example sql_gen
//! ```

use syncode::coordinator::{GenParams, Strategy};
use syncode::eval::dataset;
use syncode::eval::harness::{run_sql, EngineKind, EvalEnv};

fn main() {
    let env = EvalEnv::new("sql", 120, 160, 13);
    let tasks = dataset::spider_tasks(2, 5);
    println!("{} tasks over schema:\n{}\n", tasks.len(), tasks[0].schema_text);
    let params = GenParams {
        max_new_tokens: 60,
        strategy: Strategy::TopP { temp: 0.7, p: 0.95 },
        seed: 9,
        opportunistic: true,
        ..Default::default()
    };
    for kind in [EngineKind::Standard, EngineKind::Syncode] {
        let r = run_sql(&env, &tasks, kind, &params);
        println!(
            "{:<14} overall-acc={:>5.1}%  execute={:>5.1}%  tokens={:>5.1}  time={:.3}s",
            r.engine,
            r.overall_accuracy * 100.0,
            r.execute_pct * 100.0,
            r.avg_tokens,
            r.avg_time_s
        );
        for d in dataset::Difficulty::ALL {
            print!("    {}={:.0}%", d.name(), r.accuracy.get(&d).copied().unwrap_or(0.0) * 100.0);
        }
        println!();
    }
}
