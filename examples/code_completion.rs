//! HumanEval/MBXP-style code completion for Python and Go (the Table 3
//! workload at demo scale): complete function prefixes with and without
//! SynCode and check the results with the grammar "compilers".
//!
//! ```bash
//! cargo run --release --example code_completion
//! ```

use syncode::coordinator::{GenParams, GenRequest, Server, Strategy};
use syncode::eval::dataset;
use syncode::eval::harness::{EngineKind, EvalEnv};

fn main() {
    for lang in ["python", "go"] {
        println!("=== {lang} ===");
        let env = EvalEnv::new(lang, 80, 120, 17);
        let tasks = match lang {
            "python" => dataset::python_tasks(3, 3),
            _ => dataset::go_tasks(3, 3),
        };
        let params = GenParams {
            max_new_tokens: 70,
            strategy: Strategy::Temperature(0.6),
            seed: 21,
            opportunistic: true,
            ..Default::default()
        };
        for kind in [EngineKind::Standard, EngineKind::Syncode] {
            let srv =
                Server::start(env.model_factory(), env.tok.clone(), env.engine_factory(kind));
            println!("--- {} ---", kind.name());
            for t in &tasks {
                let r = srv.generate(GenRequest {
                    id: t.id,
                    prompt: t.prefix.clone(),
                    constraint_prefix: t.prefix.clone(),
                    grammar: None,
                    params: params.clone(),
                    token_sink: None,
                })
                .expect_served("code_completion example");
                let full = format!("{}{}", t.prefix, r.text);
                let ok = env.cx.check_complete(full.as_bytes()).is_ok();
                println!(
                    "task {} [{:?}] syntax-valid={} ({} tokens)",
                    t.id, r.finish, ok, r.tokens
                );
                if t.id == tasks[0].id {
                    for line in full.lines().take(8) {
                        println!("    | {line}");
                    }
                }
            }
            srv.shutdown();
        }
    }
}
